//! The experiment world: wires controller, testers, clock sync, the WAN
//! and a target service into the discrete-event engine and runs a full
//! DiPerF experiment.
//!
//! This is the simulation twin of the paper's deployment: one controller
//! machine, one target-service machine and one time-stamp server on the
//! "UofC" LAN, plus N wide-area tester nodes.  Every protocol action —
//! client-code distribution, staggered tester starts, each client's RPC,
//! the five-minute sync exchanges, sample streaming, failure detection —
//! is an explicit event with network latency applied, so framework
//! artifacts (sync error, report latency, ramp shape) appear in the data
//! exactly as they did on PlanetLab.
//!
//! Failure injection: [`ExperimentConfig::scenario`] compiles (see
//! [`crate::scenario`]) into a concrete fault schedule before the loop
//! starts; each fault is one DES event, so churn, network weather and
//! service outages replay bit-identically from the seed.  Messages are
//! genuinely droppable here — loss and partitions are applied on every
//! control-plane and data-plane leg — which is what finally exercises
//! the controller's silence eviction and late-join paths with real
//! inputs.
//!
//! Scale-out mechanics: [`RunOptions`] selects the engine's event queue
//! (timer wheel vs reference heap, [`crate::sim::QueueKind`]), the
//! sample-collection mode (retain vs streaming,
//! [`crate::metrics::CollectionMode`]), and the world-map layout
//! (dense ID-indexed vectors vs the classic `FxHashMap`s,
//! [`MapKind`]).  None of these knobs perturbs the simulation — every
//! combination replays the same seed to the same event sequence — they
//! only change how fast it runs and how much memory collection takes,
//! which is what makes 100 000-tester churn sweeps practical (see
//! `rust/benches/bench_scale.rs`).
//!
//! Beyond one core: [`RunOptions::shards`] routes the run through the
//! sharded world in [`shard`] — per-shard engines exchanging
//! timestamped cross-shard messages under a conservative lookahead
//! derived from [`crate::net::NetModel::min_latency_bound`].  The
//! sharded world is its own deterministic simulation (bit-identical at
//! *any* shard count, including 1), distinct from the single-engine
//! world above.

pub mod presets;
pub mod shard;

use crate::client;
use crate::cluster::{Testbed, TestbedParams};
use crate::controller::{Controller, ControllerConfig, CtrlAction};
use crate::ids::{RequestId, TesterId};
use crate::metrics::{AnalysisGrid, CollectionMode, RunData, StreamAgg};
use crate::net::NetModel;
use crate::scenario::{Fault, FaultKind, Scenario};
use crate::services::{
    gram_prews::{GramPrews, GramPrewsParams},
    gram_ws::{GramWs, GramWsParams},
    http::{HttpParams, HttpService},
    http11::{Http11Params, Http11Service},
    Service, ServiceStats, SvcOut,
};
use crate::sim::{Engine, QueueKind, SimDuration, SimTime};
use crate::tester::{Phase, Tester};
use crate::timesync::{SyncAccuracy, SyncPoint};
use crate::transport::{
    ClientCode, CtrlMsg, GoodbyeReason, TesterMsg,
};
use crate::util::{FxHashMap, Pcg64};

/// Which target service to deploy (with calibration).
#[derive(Clone, Debug)]
pub enum ServiceKind {
    /// GT3.2 pre-WS GRAM model.
    GramPrews(GramPrewsParams),
    /// GT3.2 WS GRAM model.
    GramWs(GramWsParams),
    /// Apache + CGI model.
    Http(HttpParams),
    /// Apache + CGI behind a real HTTP/1.1 front end (connect, parse
    /// and keep-alive costs modeled) — the `--protocol http11` twin.
    Http11(Http11Params),
}

impl ServiceKind {
    fn build(&self, speed: f64) -> Box<dyn Service> {
        match self {
            ServiceKind::GramPrews(p) => {
                let mut p = p.clone();
                p.speed = speed;
                Box::new(GramPrews::new(p))
            }
            ServiceKind::GramWs(p) => {
                let mut p = p.clone();
                p.speed = speed;
                Box::new(GramWs::new(p))
            }
            ServiceKind::Http(p) => {
                let mut p = p.clone();
                p.speed = speed;
                Box::new(HttpService::new(p))
            }
            ServiceKind::Http11(p) => {
                let mut p = p.clone();
                p.base.speed = speed;
                Box::new(Http11Service::new(p))
            }
        }
    }

    /// Service label (for reports).
    pub fn label(&self) -> &'static str {
        match self {
            ServiceKind::GramPrews(_) => "gt3.2-prews-gram",
            ServiceKind::GramWs(_) => "gt3.2-ws-gram",
            ServiceKind::Http(_) => "apache-cgi",
            ServiceKind::Http11(_) => "apache-cgi-http11",
        }
    }
}

/// Full experiment specification.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Master seed; every component stream derives from it.
    pub seed: u64,
    /// Target service + calibration.
    pub service: ServiceKind,
    /// Testbed synthesis parameters (tester count lives here).
    pub testbed: TestbedParams,
    /// Controller policy (stagger, eviction, test description).
    pub controller: ControllerConfig,
    /// Client-code payload for the deploy phase.
    pub code: ClientCode,
    /// Extra time after the last tester's duration before the
    /// experiment is cut off.
    pub grace_s: f64,
    /// Fault-injection scenario (churn, weather, service outages);
    /// [`Scenario::none`] for a quiet run.
    pub scenario: Scenario,
}

/// World-map layout of the single-engine runner's hot path.
///
/// Request ids and truth keys are dense and monotone, so hash maps buy
/// nothing over ID-indexed vectors — [`MapKind::Dense`] replaces them
/// with a ring-buffer request table and per-tester truth columns.  The
/// classic layout stays selectable so the dense path is pinned by a
/// differential test (`rust/tests/shard_differential.rs`): both layouts
/// must replay a seed to bit-identical reports.
#[derive(Clone, Copy, Debug, Eq, PartialEq)]
pub enum MapKind {
    /// Dense ID-indexed vectors (default; the flattened hot path).
    Dense,
    /// The original `FxHashMap` world maps (differential reference).
    Hash,
}

impl MapKind {
    /// Stable label for reports and CLI flags.
    pub fn label(self) -> &'static str {
        match self {
            MapKind::Dense => "dense",
            MapKind::Hash => "hash",
        }
    }
}

/// Run-mechanics knobs orthogonal to the experiment specification: how
/// samples are collected, which event queue the engine runs on, and how
/// the world maps are laid out.  None of them changes the simulated
/// world — a given seed dispatches the identical event sequence under
/// every combination.  [`RunOptions::shards`] is the exception by
/// design: it selects the sharded runner, a *different* deterministic
/// world (own RNG stream layout) that is itself invariant across shard
/// counts.
#[derive(Clone, Copy, Debug)]
pub struct RunOptions {
    /// Sample collection strategy (default: retain, the classic path).
    pub collect: CollectionMode,
    /// Event-queue implementation (default: the timer wheel).
    pub queue: QueueKind,
    /// Streaming-grid resolution in quanta (default 512, matching the
    /// AOT analysis variants).
    pub num_quanta: usize,
    /// Moving-average window in seconds (default 160, the paper's
    /// Figure 3 window).
    pub window_s: f64,
    /// World-map layout of the single-engine hot path (default: dense).
    pub map: MapKind,
    /// Run the sharded world on this many per-core engines (`None` =
    /// the single-engine runner).  Reports are bit-identical at every
    /// shard count, including `Some(1)`; see [`shard`].
    pub shards: Option<usize>,
}

impl Default for RunOptions {
    fn default() -> RunOptions {
        RunOptions {
            collect: CollectionMode::Retain,
            queue: QueueKind::Wheel,
            num_quanta: 512,
            window_s: 160.0,
            map: MapKind::Dense,
            shards: None,
        }
    }
}

/// Everything a finished experiment produces.
pub struct ExperimentResult {
    /// Reconciled samples + per-tester records (samples empty in
    /// streaming mode).
    pub data: RunData,
    /// Service-side counters.
    pub service_stats: ServiceStats,
    /// Service label.
    pub service_name: &'static str,
    /// Clock-sync accuracy over all sync exchanges (vs simulation truth).
    pub sync: SyncAccuracy,
    /// DES events dispatched.
    pub events: u64,
    /// Wall-clock milliseconds spent simulating.
    pub wall_ms: f64,
    /// Service stalls observed (WS GRAM only; 0 otherwise).
    pub stalls: u64,
    /// Scenario faults scheduled for this run (0 for a quiet run).
    pub faults: u64,
    /// The analysis grid fixed at ramp time (both collection modes
    /// report it, so retained runs can be analyzed comparably).
    pub grid: AnalysisGrid,
    /// Streaming aggregation state (streaming mode only).
    pub stream: Option<StreamAgg>,
    /// High-water mark of pending DES events.
    pub peak_pending: u64,
    /// Which event queue ran the experiment.
    pub queue: QueueKind,
    /// Which collection mode ran the experiment.
    pub collection: CollectionMode,
}

/// Events of the DiPerF world.
enum Ev {
    /// scp of the client code to tester `i` completed.
    DeployDone(usize),
    /// Controller message delivered at tester `i`.
    CtrlDeliver(usize, CtrlMsg),
    /// Tester report delivered at the controller.
    TesterDeliver(usize, TesterMsg),
    /// Controller decides to start tester `i` (per the ramp schedule).
    StartTester(usize),
    /// Retransmit Start to tester `i` if it still has not come up (the
    /// one-shot Start can be lost to weather or a crashed node; ssh
    /// would retry, so the controller does too).  `attempt` bounds the
    /// chain.
    StartRetry(usize, u32),
    /// Tester `i` launches its next client.
    ClientLaunch(usize),
    /// A client's request reaches the service.
    RequestArrive(RequestId),
    /// A service wake (PS completion horizon) fires; the tag must match
    /// the world's armed wake or the event is stale and skipped.
    ServiceWake(u64),
    /// The service's response for `req` reaches its tester.
    ResponseDeliver(RequestId, crate::services::Outcome),
    /// Periodic tester-timeout sweep (§3 failure #1).  One recurring
    /// event replaces a per-launch timeout event: stale timeouts used to
    /// sit in the heap for the full timeout window and dominated heap
    /// traffic (see EXPERIMENTS.md §Perf).
    TimeoutSweep,
    /// Tester `i`'s sync request reaches the time server.
    SyncReqArrive(usize, f64),
    /// The sync reply reaches tester `i` (server reading attached).
    SyncReplyArrive(usize, f64, f64),
    /// Tester `i` begins its next sync exchange.  The generation tag
    /// keeps exactly one chain alive per tester across crash/restart
    /// cycles: stale chain events compare unequal and die out.
    SyncBegin(usize, u32),
    /// Node under tester `i` dies permanently (testbed reliability, as
    /// opposed to scenario churn which may restart it).
    NodeFail(usize),
    /// Scenario fault `k` (index into the compiled schedule) fires.
    Fault(usize),
    /// Controller liveness sweep.
    CtrlTick,
}

/// Dense in-flight request table.
///
/// Request ids are allocated monotonically, so the live ids always fall
/// in a contiguous window `[base, base + ring.len())` — a ring buffer of
/// `Option<tester>` indexed by `id - base` replaces the hash map.  The
/// window is kept short by eagerly removing entries on completion /
/// timeout and by [`World::abandon_outstanding`] at every stop/kill
/// site, so a request orphaned by a dying tester cannot pin `base`.
#[derive(Default)]
struct ReqTable {
    base: u32,
    ring: std::collections::VecDeque<Option<u32>>,
}

impl ReqTable {
    fn insert(&mut self, id: u32, tester: u32) {
        debug_assert_eq!(
            id,
            self.base.wrapping_add(self.ring.len() as u32),
            "request ids must be allocated monotonically"
        );
        self.ring.push_back(Some(tester));
    }

    fn get(&self, id: u32) -> Option<u32> {
        let idx = id.checked_sub(self.base)? as usize;
        self.ring.get(idx).copied().flatten()
    }

    fn remove(&mut self, id: u32) -> Option<u32> {
        let idx = id.checked_sub(self.base)? as usize;
        let t = self.ring.get_mut(idx)?.take();
        while let Some(None) = self.ring.front() {
            self.ring.pop_front();
            self.base = self.base.wrapping_add(1);
        }
        t
    }
}

/// The in-flight request map under either [`MapKind`] layout.  The two
/// arms are operation-for-operation equivalent, so the simulation is
/// identical under both (enforced by the shard-differential suite).
enum ReqMap {
    Hash(FxHashMap<u32, u32>),
    Dense(ReqTable),
}

impl ReqMap {
    fn new(kind: MapKind) -> ReqMap {
        match kind {
            MapKind::Hash => ReqMap::Hash(FxHashMap::default()),
            MapKind::Dense => ReqMap::Dense(ReqTable::default()),
        }
    }

    fn insert(&mut self, id: u32, tester: u32) {
        match self {
            ReqMap::Hash(m) => {
                m.insert(id, tester);
            }
            ReqMap::Dense(t) => t.insert(id, tester),
        }
    }

    fn get(&self, id: u32) -> Option<u32> {
        match self {
            ReqMap::Hash(m) => m.get(&id).copied(),
            ReqMap::Dense(t) => t.get(id),
        }
    }

    fn remove(&mut self, id: u32) -> Option<u32> {
        match self {
            ReqMap::Hash(m) => m.remove(&id),
            ReqMap::Dense(t) => t.remove(id),
        }
    }
}

/// Simulation-truth store (`(tester, seq) -> true end time`) under
/// either layout: sequence numbers are per-tester monotone from zero,
/// so the dense arm is a per-tester column vector indexed by `seq`.
enum TruthStore {
    Hash(FxHashMap<(u32, u32), f64>),
    Dense(Vec<Vec<f64>>),
}

impl TruthStore {
    fn new(kind: MapKind, n: usize) -> TruthStore {
        match kind {
            MapKind::Hash => TruthStore::Hash(FxHashMap::default()),
            MapKind::Dense => TruthStore::Dense(vec![Vec::new(); n]),
        }
    }

    fn insert(&mut self, tester: u32, seq: u32, t: f64) {
        match self {
            TruthStore::Hash(m) => {
                m.insert((tester, seq), t);
            }
            TruthStore::Dense(v) => {
                let col = &mut v[tester as usize];
                let idx = seq as usize;
                if idx >= col.len() {
                    col.resize(idx + 1, f64::NAN);
                }
                col[idx] = t;
            }
        }
    }

    fn get(&self, tester: u32, seq: u32) -> f64 {
        match self {
            TruthStore::Hash(m) => {
                m.get(&(tester, seq)).copied().unwrap_or(f64::NAN)
            }
            TruthStore::Dense(v) => v
                .get(tester as usize)
                .and_then(|col| col.get(seq as usize))
                .copied()
                .unwrap_or(f64::NAN),
        }
    }
}

/// The combined effect of overlapping weather spells on one node: the
/// worst latency factor, summed loss (clamped), partitioned if any
/// spell partitions.  Empty input means clear skies.
pub(crate) fn combine_weather(
    spells: &[(u64, crate::scenario::WeatherPatch)],
) -> crate::scenario::WeatherPatch {
    let mut p = crate::scenario::WeatherPatch::clear();
    for &(_, s) in spells {
        p.latency_factor = p.latency_factor.max(s.latency_factor);
        p.extra_loss = (p.extra_loss + s.extra_loss).min(1.0);
        p.partitioned = p.partitioned || s.partitioned;
    }
    p
}

/// The running world.
struct World {
    eng: Engine<Ev>,
    bed: Testbed,
    net: NetModel,
    controller: Controller,
    testers: Vec<Tester>,
    service: Box<dyn Service>,
    /// Per-component RNG streams (deterministic regardless of order).
    rng_net: Pcg64,
    rng_svc: Pcg64,
    rng_testers: Vec<Pcg64>,
    reqs: ReqMap,
    next_req: u32,
    /// Simulation truth for validation: (tester, seq) -> true end time.
    /// Populated only in retain mode — it is O(calls) by nature and the
    /// sync-validation tests that consume it need the samples anyway.
    truth: TruthStore,
    /// SoA timeout prefilter: per-tester global-time lower bound on when
    /// the outstanding invocation *could* time out (`INFINITY` when the
    /// tester has nothing that can expire).  The sweep skips testers
    /// whose bound is in the future without touching their `Tester`
    /// struct; the exact local-clock check in the sweep body remains the
    /// sole decision-maker, so the prefilter cannot change behavior.
    deadline: Vec<f64>,
    sync: SyncAccuracy,
    deploys_pending: usize,
    ramp_begun: bool,
    horizon: SimTime,
    /// Run-mechanics options (collection mode, queue choice, grid).
    opts: RunOptions,
    /// The analysis grid, fixed once the ramp schedule is known.
    grid: Option<AnalysisGrid>,
    /// Copy of the config's grace window (for the planned grid span).
    grace_s: f64,
    /// The earliest armed service wake (dedupe: stale ServiceWake events
    /// whose tag mismatches are dropped, so wake chains cannot multiply).
    svc_wake: Option<u64>,
    /// Compiled scenario fault schedule (index = event payload).
    faults: Vec<Fault>,
    /// Pairing state: the scenario crash currently holding each tester
    /// down (a restart applies only if its token still matches; `None`
    /// after a permanent testbed failure so nothing revives it).
    crash_token: Vec<Option<u64>>,
    /// Controller-side session teardown per tester (set on eviction).
    /// Transport sessions are connection-oriented: even when the Stop
    /// payload is lost, the teardown itself is observable — the
    /// tester's next *delivered* write hits a closed session (TCP RST /
    /// dead ssh channel) and the tester stops issuing clients on the
    /// spot (§3).  A Hello opens a fresh session and clears the flag.
    session_closed: Vec<bool>,
    /// Active weather spells per tester node (token -> patch).  A node
    /// under several overlapping spells gets their *combined* effect;
    /// each clear removes only its own spell.
    weather_spells: Vec<Vec<(u64, crate::scenario::WeatherPatch)>>,
    /// Active service degradations (token -> factor).  Overlapping
    /// degradations combine as "worst wins"; each restore removes only
    /// its own entry.
    degrade_spells: Vec<(u64, f64)>,
}

impl World {
    fn local(&self, i: usize) -> f64 {
        self.bed
            .node(self.testers[i].node)
            .clock
            .local_secs(self.eng.now())
    }

    /// Convert a tester-local target time to global for scheduling.
    fn local_to_global(&self, i: usize, local: f64) -> SimTime {
        let g = self
            .bed
            .node(self.testers[i].node)
            .clock
            .global_secs(local);
        SimTime::from_secs_f64(g.max(self.eng.now().as_secs_f64()))
    }

    fn send_to_controller(&mut self, i: usize, msg: TesterMsg) {
        let node = self.testers[i].node;
        if self.testers[i].phase == Phase::Dead || !self.bed.is_up(node) {
            return;
        }
        if self.net.lost(node, self.bed.controller, &mut self.rng_net) {
            return;
        }
        if matches!(msg, TesterMsg::Hello) {
            // re-registration rides a fresh connection
            self.session_closed[i] = false;
        } else if self.session_closed[i] {
            // The controller tore this session down (eviction).  The
            // write that just got through is answered with a reset, and
            // the tester stops issuing clients immediately — §3's
            // "an unmonitored client never loads the service".
            self.abandon_outstanding(i);
            self.testers[i].session_lost();
            return;
        }
        let lat = self
            .net
            .latency(node, self.bed.controller, &mut self.rng_net);
        self.eng.schedule_in(lat, Ev::TesterDeliver(i, msg));
    }

    fn send_to_tester(&mut self, i: usize, msg: CtrlMsg) {
        let node = self.testers[i].node;
        if self.net.lost(self.bed.controller, node, &mut self.rng_net) {
            return;
        }
        let lat = self
            .net
            .latency(self.bed.controller, node, &mut self.rng_net);
        self.eng.schedule_in(lat, Ev::CtrlDeliver(i, msg));
    }

    fn handle_svc_outs(&mut self, outs: Vec<SvcOut>) {
        for o in outs {
            match o {
                SvcOut::Wake { at } => {
                    let tag = at.as_micros().max(self.eng.now().as_micros());
                    if self.svc_wake.is_none_or(|w| tag < w) {
                        self.svc_wake = Some(tag);
                        self.eng
                            .schedule(SimTime(tag), Ev::ServiceWake(tag));
                    }
                }
                SvcOut::Done { req, outcome, .. } => {
                    if let Some(tester) = self.reqs.get(req.0) {
                        let node = self.testers[tester as usize].node;
                        if self.net.lost(self.bed.service, node, &mut self.rng_net) {
                            // the response is gone for good: drop the
                            // request record; the tester's timeout fires
                            self.reqs.remove(req.0);
                            continue;
                        }
                        let lat =
                            self.net.latency(self.bed.service, node, &mut self.rng_net);
                        self.eng
                            .schedule_in(lat, Ev::ResponseDeliver(req, outcome));
                    }
                }
            }
        }
    }

    /// Schedule tester `i`'s next client launch (local pacing -> global).
    fn schedule_next_launch(&mut self, i: usize) {
        let now_local = self.local(i);
        let t = self.testers[i].next_launch_local(now_local);
        let at = self.local_to_global(i, t);
        self.eng.schedule(at, Ev::ClientLaunch(i));
    }

    /// Drop the request-table entry for tester `i`'s in-flight
    /// invocation, if any.  Called wherever a tester stops or dies with
    /// a request still outstanding — the entry would otherwise never be
    /// removed (the tester's timeout sweep no longer sees the
    /// invocation), which under the dense layout would pin the ring
    /// buffer's `base` for the rest of the run.  Applied under *both*
    /// map layouts so they stay differential-identical.
    fn abandon_outstanding(&mut self, i: usize) {
        if let Some(inv) = self.testers[i].outstanding {
            self.reqs.remove(inv.req.0);
        }
        self.deadline[i] = f64::INFINITY;
    }

    /// Tester produced a sample: forward it, apply the give-up policy,
    /// and keep the loop going.
    fn after_sample(&mut self, i: usize, sample: crate::metrics::CallSample) {
        if self.opts.collect == CollectionMode::Retain {
            self.truth.insert(
                sample.tester.0,
                sample.seq,
                self.eng.now().as_secs_f64(),
            );
        }
        self.send_to_controller(i, TesterMsg::Sample(sample));
        let give_up = self.testers[i].desc.give_up_failures;
        if self.testers[i].should_give_up(give_up) {
            self.testers[i].stop();
            self.send_to_controller(
                i,
                TesterMsg::Goodbye(GoodbyeReason::TooManyFailures),
            );
            return;
        }
        if self.testers[i].phase == Phase::Running {
            if self.testers[i].duration_elapsed(self.local(i)) {
                self.testers[i].stop();
                self.send_to_controller(
                    i,
                    TesterMsg::Goodbye(GoodbyeReason::Finished),
                );
            } else {
                self.schedule_next_launch(i);
            }
        }
    }

    /// Re-apply the combined service degradation: the worst (smallest)
    /// active factor wins; full speed when no degradation is active.
    fn apply_degrade(&mut self) {
        let factor = self
            .degrade_spells
            .iter()
            .map(|&(_, f)| f)
            .fold(1.0, f64::min);
        let outs = self.service.set_speed_factor(self.eng.now(), factor);
        self.handle_svc_outs(outs);
    }

    /// Execute one compiled scenario fault.  Pairing tokens make
    /// overlapping faults safe: an undo applies only if its setter is
    /// still the one in effect.
    fn apply_fault(&mut self, k: usize) {
        let f = self.faults[k];
        match f.kind {
            FaultKind::Crash { tester, token } => {
                if self.testers[tester].phase != Phase::Dead {
                    self.abandon_outstanding(tester);
                    self.testers[tester].kill();
                    self.bed.set_down(self.testers[tester].node);
                    self.crash_token[tester] = Some(token);
                }
            }
            FaultKind::Restart { tester, token } => {
                if self.crash_token[tester] != Some(token) {
                    return; // superseded or permanently failed
                }
                self.crash_token[tester] = None;
                self.bed.set_up(self.testers[tester].node);
                if self.testers[tester].revive() == Phase::Running {
                    // §3 late join: re-register, restart the sync chain,
                    // and resume launching clients (immediately if the
                    // pre-crash clock map still places us on the common
                    // base, otherwise after the first fresh sync)
                    self.send_to_controller(tester, TesterMsg::Hello);
                    let gen = self.testers[tester].sync_gen;
                    self.eng
                        .schedule_in(SimDuration(0), Ev::SyncBegin(tester, gen));
                    if !self.testers[tester].clock.is_empty() {
                        self.schedule_next_launch(tester);
                    }
                }
            }
            FaultKind::Weather { tester, patch, token } => {
                self.weather_spells[tester].push((token, patch));
                self.net.set_weather(
                    self.testers[tester].node,
                    combine_weather(&self.weather_spells[tester]),
                );
            }
            FaultKind::WeatherClear { tester, token } => {
                self.weather_spells[tester].retain(|&(t, _)| t != token);
                self.net.set_weather(
                    self.testers[tester].node,
                    combine_weather(&self.weather_spells[tester]),
                );
            }
            FaultKind::Degrade { factor, token } => {
                self.degrade_spells.push((token, factor));
                self.apply_degrade();
            }
            FaultKind::DegradeRestore { token } => {
                self.degrade_spells.retain(|&(t, _)| t != token);
                self.apply_degrade();
            }
            FaultKind::RestartService => {
                let outs = self.service.restart(self.eng.now());
                self.handle_svc_outs(outs);
            }
        }
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::DeployDone(i) => {
                self.controller.deploy_finished(
                    TesterId(i as u32),
                    true,
                    self.eng.now().as_secs_f64(),
                );
                self.deploys_pending -= 1;
                if self.deploys_pending == 0 && !self.ramp_begun {
                    self.ramp_begun = true;
                    let ramp0 = self.eng.now().as_secs_f64();
                    for j in 0..self.testers.len() {
                        let at = SimTime::from_secs_f64(
                            self.controller.start_time(j, ramp0),
                        );
                        self.eng.schedule(at, Ev::StartTester(j));
                    }
                    // horizon: last start + duration + grace
                    let last = self
                        .controller
                        .start_time(self.testers.len() - 1, ramp0);
                    let duration_s = self.controller.description().duration_s;
                    self.horizon =
                        SimTime::from_secs_f64(last + duration_s + 120.0);
                    // The analysis grid is fixed here — before the first
                    // tester starts, so before the first sample — from
                    // the planned span and the declared peak window
                    // (last start .. first planned stop).  Streaming
                    // aggregation begins on it immediately.
                    let planned = self.horizon.as_secs_f64()
                        + self.grace_s.max(0.0);
                    // declared peak window: last start .. first planned
                    // stop; when the ramp outlasts the duration no
                    // all-up window exists — fall back to the middle
                    // half, mirroring `RunData::peak_window`
                    let (w0, w1) = if ramp0 + duration_s > last {
                        (last, ramp0 + duration_s)
                    } else {
                        (0.25 * planned, 0.75 * planned)
                    };
                    let grid = AnalysisGrid::planned(
                        self.opts.num_quanta,
                        self.testers.len(),
                        self.opts.window_s,
                        w0,
                        w1,
                        planned,
                    );
                    if self.opts.collect == CollectionMode::Stream {
                        self.controller.set_streaming(StreamAgg::new(grid));
                    }
                    self.grid = Some(grid);
                }
            }
            Ev::StartTester(i) => {
                self.controller
                    .mark_started(TesterId(i as u32), self.eng.now().as_secs_f64());
                self.send_to_tester(i, CtrlMsg::Start(self.controller.description()));
                self.eng
                    .schedule_in(SimDuration::from_secs(15), Ev::StartRetry(i, 1));
            }
            Ev::StartRetry(i, attempt) => {
                // Start was lost (weather, or the node was down) and the
                // tester never came up: retransmit with a bounded chain.
                // Keep retrying through Dead too — a node that crashed
                // before its Start arrived revives to Idle and still
                // needs the retransmit to ever join the run.
                if !matches!(self.testers[i].phase, Phase::Idle | Phase::Dead)
                    || attempt > 120
                {
                    return;
                }
                self.send_to_tester(i, CtrlMsg::Start(self.controller.description()));
                self.eng.schedule_in(
                    SimDuration::from_secs(15),
                    Ev::StartRetry(i, attempt + 1),
                );
            }
            Ev::CtrlDeliver(i, msg) => {
                if !self.bed.is_up(self.testers[i].node) {
                    return; // delivered to a crashed node: lost
                }
                match msg {
                    CtrlMsg::Start(desc) => {
                        if self.testers[i].phase != Phase::Idle {
                            return;
                        }
                        let now_local = self.local(i);
                        self.testers[i].start(now_local, desc);
                        // latency estimate: one ping round trip to the
                        // service
                        let rtt = self
                            .net
                            .latency(
                                self.testers[i].node,
                                self.bed.service,
                                &mut self.rng_net,
                            )
                            .as_secs_f64()
                            + self
                                .net
                                .latency(
                                    self.bed.service,
                                    self.testers[i].node,
                                    &mut self.rng_net,
                                )
                                .as_secs_f64();
                        self.testers[i].latency_estimate_s = rtt / 2.0;
                        // first sync now; first client launch follows it
                        let gen = self.testers[i].sync_gen;
                        self.eng
                            .schedule_in(SimDuration(0), Ev::SyncBegin(i, gen));
                    }
                    CtrlMsg::Stop => {
                        self.abandon_outstanding(i);
                        self.testers[i].stop();
                    }
                }
            }
            Ev::SyncBegin(i, gen) => {
                if !matches!(self.testers[i].phase, Phase::Running)
                    || gen != self.testers[i].sync_gen
                {
                    return;
                }
                // The chain drives itself from here (not from the reply)
                // so a lost packet delays one exchange instead of
                // silencing all future syncs.
                let l1 = self.local(i);
                let next_local = l1 + self.testers[i].desc.sync_interval_s;
                let at = self.local_to_global(i, next_local);
                self.eng.schedule(at, Ev::SyncBegin(i, gen));
                let node = self.testers[i].node;
                if self.net.lost(node, self.bed.time_server, &mut self.rng_net) {
                    return;
                }
                let lat = self
                    .net
                    .latency(node, self.bed.time_server, &mut self.rng_net);
                self.eng.schedule_in(lat, Ev::SyncReqArrive(i, l1));
            }
            Ev::SyncReqArrive(i, l1) => {
                // the server stamps its own clock reading
                let server = self
                    .bed
                    .node(self.bed.time_server)
                    .clock
                    .local_secs(self.eng.now());
                let node = self.testers[i].node;
                if self.net.lost(self.bed.time_server, node, &mut self.rng_net) {
                    return;
                }
                let lat = self
                    .net
                    .latency(self.bed.time_server, node, &mut self.rng_net);
                self.eng
                    .schedule_in(lat, Ev::SyncReplyArrive(i, l1, server));
            }
            Ev::SyncReplyArrive(i, l1, server) => {
                if self.testers[i].phase == Phase::Dead
                    || !self.bed.is_up(self.testers[i].node)
                {
                    return;
                }
                let l2 = self.local(i);
                let p = SyncPoint { l1, server, l2 };
                let first = self.testers[i].clock.is_empty();
                self.testers[i].record_sync(p);
                // accuracy vs simulation truth, at the reply instant
                if let Some(est) = self.testers[i].clock.to_global(l2) {
                    let truth = self.eng.now().as_secs_f64();
                    self.sync.push(est - truth, p.rtt());
                }
                self.send_to_controller(i, TesterMsg::Sync(p));
                if self.testers[i].phase == Phase::Running && first {
                    self.schedule_next_launch(i);
                }
            }
            Ev::ClientLaunch(i) => {
                if !self.testers[i].can_launch(self.local(i)) {
                    // duration elapsed or a client is still outstanding
                    if self.testers[i].phase == Phase::Running
                        && self.testers[i].outstanding.is_none()
                        && self.testers[i].duration_elapsed(self.local(i))
                    {
                        self.testers[i].stop();
                        self.send_to_controller(
                            i,
                            TesterMsg::Goodbye(GoodbyeReason::Finished),
                        );
                    }
                    return;
                }
                let now_local = self.local(i);
                let earliest = self.testers[i].next_launch_local(now_local);
                if earliest - now_local > 1e-3 {
                    // an early stale event (e.g. a pre-crash launch chain
                    // surviving a quick restart): re-anchor to the pacing
                    // instead of violating the configured rate
                    let at = self.local_to_global(i, earliest);
                    self.eng.schedule(at, Ev::ClientLaunch(i));
                    return;
                }
                let node = self.bed.node(self.testers[i].node).clone();
                if !client::try_start(
                    node.client_start_failure,
                    &mut self.rng_testers[i],
                ) {
                    let s = self.testers[i].record_start_failure(now_local);
                    self.after_sample(i, s);
                    return;
                }
                let req = RequestId(self.next_req);
                self.next_req += 1;
                let inv = self.testers[i].launch(now_local, req);
                self.reqs.insert(req.0, i as u32);
                // timeout prefilter bound: the invocation cannot expire
                // before its local deadline maps back to global time (a
                // hair early for float safety; the sweep re-checks
                // exactly)
                self.deadline[i] = node
                    .clock
                    .global_secs(inv.launched_local + self.testers[i].desc.timeout_s)
                    - 1e-6;
                // client exec overhead before the RPC leaves the node
                let pre =
                    client::exec_overhead_s(node.cpu_speed, &mut self.rng_testers[i]);
                if self.net.lost(
                    self.testers[i].node,
                    self.bed.service,
                    &mut self.rng_net,
                ) {
                    // the RPC vanished in the WAN; the tester's timeout
                    // sweep will classify the invocation
                    return;
                }
                let lat = self.net.latency(
                    self.testers[i].node,
                    self.bed.service,
                    &mut self.rng_net,
                );
                self.eng.schedule_in(
                    SimDuration::from_secs_f64(pre) + lat,
                    Ev::RequestArrive(req),
                );
                let _ = inv; // timeout handled by the periodic sweep
            }
            Ev::RequestArrive(req) => {
                let Some(client_id) = self.reqs.get(req.0) else {
                    return;
                };
                let outs = self.service.submit(
                    self.eng.now(),
                    req,
                    client_id,
                    &mut self.rng_svc,
                );
                self.handle_svc_outs(outs);
            }
            Ev::ServiceWake(tag) => {
                if self.svc_wake != Some(tag) {
                    return; // superseded by an earlier wake
                }
                self.svc_wake = None;
                let outs = self.service.on_wake(self.eng.now(), &mut self.rng_svc);
                self.handle_svc_outs(outs);
            }
            Ev::ResponseDeliver(req, outcome) => {
                let Some(tester) = self.reqs.remove(req.0) else {
                    return;
                };
                let i = tester as usize;
                if self.testers[i].phase == Phase::Dead {
                    return;
                }
                let now_local = self.local(i);
                let node = self.bed.node(self.testers[i].node).clone();
                let post =
                    client::exec_overhead_s(node.cpu_speed, &mut self.rng_testers[i]);
                if let Some(s) = self.testers[i].record_result(
                    now_local,
                    req,
                    client::classify(outcome),
                    post,
                ) {
                    self.deadline[i] = f64::INFINITY;
                    self.after_sample(i, s);
                }
            }
            Ev::TimeoutSweep => {
                let now_g = self.eng.now().as_secs_f64();
                for i in 0..self.testers.len() {
                    // SoA fast path: nothing of tester `i` can have
                    // expired yet — skip without touching its struct
                    if now_g < self.deadline[i] {
                        continue;
                    }
                    if self.testers[i].phase == Phase::Dead {
                        self.deadline[i] = f64::INFINITY;
                        continue;
                    }
                    let Some(inv) = self.testers[i].outstanding else {
                        self.deadline[i] = f64::INFINITY;
                        continue;
                    };
                    let now_local = self.local(i);
                    if now_local - inv.launched_local
                        < self.testers[i].desc.timeout_s
                    {
                        continue;
                    }
                    if let Some(s) = self.testers[i]
                        .record_timeout(now_local, inv.timeout_token)
                    {
                        // the request's eventual response must be ignored
                        self.reqs.remove(inv.req.0);
                        self.deadline[i] = f64::INFINITY;
                        self.after_sample(i, s);
                    }
                }
                self.eng
                    .schedule_in(SimDuration::from_secs(5), Ev::TimeoutSweep);
            }
            Ev::TesterDeliver(i, msg) => {
                let action = self.controller.on_msg(
                    self.eng.now().as_secs_f64(),
                    TesterId(i as u32),
                    msg,
                );
                if let Some(CtrlAction::Evict(t)) = action {
                    // eviction tears the session down; the Stop payload
                    // may still be lost, but the teardown is observable
                    self.session_closed[t.index()] = true;
                    self.send_to_tester(t.index(), CtrlMsg::Stop);
                }
            }
            Ev::NodeFail(i) => {
                self.abandon_outstanding(i);
                self.testers[i].kill();
                self.bed.set_down(self.testers[i].node);
                // permanent: no scenario restart may revive this node
                self.crash_token[i] = None;
            }
            Ev::Fault(k) => {
                self.apply_fault(k);
            }
            Ev::CtrlTick => {
                let now = self.eng.now().as_secs_f64();
                for a in self.controller.check_liveness(now) {
                    let CtrlAction::Evict(t) = a;
                    self.session_closed[t.index()] = true;
                    self.send_to_tester(t.index(), CtrlMsg::Stop);
                }
                // Tester-side re-registration loop: a running tester the
                // controller has evicted keeps offering Hello until one
                // gets through (the revive-time Hello can be lost to
                // weather, and a late Start can land after a silence
                // eviction).
                for i in 0..self.testers.len() {
                    if self.testers[i].phase == Phase::Running
                        && self.controller.is_evicted(TesterId(i as u32))
                    {
                        self.send_to_controller(i, TesterMsg::Hello);
                    }
                }
                self.eng
                    .schedule_in(SimDuration::from_secs(30), Ev::CtrlTick);
            }
        }
    }
}

/// Run a complete DiPerF experiment with the default mechanics
/// (retained samples, timer-wheel queue).
pub fn run_experiment(cfg: &ExperimentConfig) -> ExperimentResult {
    run_experiment_opts(cfg, RunOptions::default())
}

/// Run a complete DiPerF experiment with explicit run mechanics.
///
/// ```
/// use diperf::experiment::{presets, run_experiment_opts, RunOptions};
/// use diperf::metrics::CollectionMode;
///
/// let cfg = presets::quick_http(2, 20.0, 1);
/// let opts = RunOptions {
///     collect: CollectionMode::Stream,
///     ..RunOptions::default()
/// };
/// let r = run_experiment_opts(&cfg, opts);
/// assert!(r.data.samples.is_empty(), "streaming retains no samples");
/// let agg = r.stream.expect("streaming aggregator");
/// assert!(agg.binned.total_ok > 0.0);
/// ```
pub fn run_experiment_opts(
    cfg: &ExperimentConfig,
    opts: RunOptions,
) -> ExperimentResult {
    if let Some(shards) = opts.shards {
        return shard::run_experiment_sharded(cfg, opts, shards.max(1));
    }
    let wall = std::time::Instant::now();
    let mut root = Pcg64::seed_from(cfg.seed);
    let mut rng_bed = root.split(1);
    let bed = Testbed::generate(&cfg.testbed, &mut rng_bed);
    let n = bed.testers.len();

    let service = cfg
        .service
        .build(bed.node(bed.service).cpu_speed);
    let controller = Controller::new(cfg.controller.clone(), &bed.testers);
    let testers: Vec<Tester> = bed
        .testers
        .iter()
        .enumerate()
        .map(|(i, &node)| Tester::new(TesterId(i as u32), node))
        .collect();
    let rng_testers: Vec<Pcg64> =
        (0..n).map(|i| root.split(100 + i as u64)).collect();

    let mut w = World {
        eng: Engine::with_queue(opts.queue),
        net: bed.net.clone(),
        controller,
        testers,
        service,
        rng_net: root.split(2),
        rng_svc: root.split(3),
        rng_testers,
        reqs: ReqMap::new(opts.map),
        next_req: 0,
        truth: TruthStore::new(opts.map, n),
        deadline: vec![f64::INFINITY; n],
        sync: SyncAccuracy::new(),
        deploys_pending: n,
        ramp_begun: false,
        horizon: SimTime::MAX,
        opts,
        grid: None,
        grace_s: cfg.grace_s,
        svc_wake: None,
        faults: Vec::new(),
        crash_token: vec![None; n],
        session_closed: vec![false; n],
        weather_spells: vec![Vec::new(); n],
        degrade_spells: Vec::new(),
        bed,
    };

    // deploy phase: scp the client code to every tester node
    let mut rng_deploy = root.split(4);
    for i in 0..n {
        let dt = w.net.transfer_time(
            w.bed.controller,
            w.testers[i].node,
            cfg.code.bytes(),
            &mut rng_deploy,
        );
        w.eng.schedule(SimTime(0) + dt, Ev::DeployDone(i));
    }
    // node-failure injection
    let duration =
        SimDuration::from_secs_f64(cfg.controller.desc.duration_s * 2.0);
    let mut rng_fail = root.split(5);
    for i in 0..n {
        if let Some(at) =
            w.bed
                .sample_failure_time(w.testers[i].node, duration, &mut rng_fail)
        {
            w.eng.schedule(at, Ev::NodeFail(i));
        }
    }
    // scenario fault injection: compile every random choice up front
    // (dedicated stream -> the schedule is a pure function of the seed)
    debug_assert!(cfg.scenario.validate().is_ok(), "invalid scenario");
    let mut rng_scn = root.split(6);
    let scn_horizon_s = n as f64 * cfg.controller.stagger_s
        + cfg.controller.desc.duration_s * 2.0;
    w.faults = cfg.scenario.compile(n, scn_horizon_s, &mut rng_scn);
    for (k, f) in w.faults.iter().enumerate() {
        w.eng
            .schedule(SimTime::from_secs_f64(f.at_s), Ev::Fault(k));
    }
    w.eng.schedule(SimTime(0), Ev::CtrlTick);
    w.eng.schedule(SimTime(0), Ev::TimeoutSweep);

    // main loop (horizon is set once the ramp schedule is known)
    let run_span = crate::obsv::span!(crate::obsv::Kind::SimRun, n as u64);
    loop {
        let horizon = w.horizon
            + SimDuration::from_secs_f64(cfg.grace_s.max(0.0));
        let Some((_, ev)) = ({
            if w.eng.pending() == 0 || w.eng.now() > horizon {
                None
            } else {
                w.eng.next()
            }
        }) else {
            break;
        };
        w.handle(ev);
    }
    w.eng.flush_obsv();
    drop(run_span);

    let duration_s = w.eng.now().as_secs_f64();
    let mut data = w.controller.finalize(duration_s);
    // backfill simulation truth for sync-pipeline validation
    for s in data.samples.iter_mut() {
        s.t_end_true = w.truth.get(s.tester.0, s.seq);
    }
    let stream = w.controller.take_stream();
    // A run that never reached the ramp (nothing deployed) falls back to
    // an observed-duration grid so downstream code always has one.
    let grid = w.grid.unwrap_or_else(|| {
        AnalysisGrid::planned(
            opts.num_quanta,
            n,
            opts.window_s,
            0.0,
            duration_s,
            duration_s,
        )
    });

    ExperimentResult {
        data,
        service_stats: w.service.stats(),
        service_name: w.service.name(),
        stalls: w.service.stalls(),
        sync: w.sync,
        events: w.eng.processed(),
        wall_ms: wall.elapsed().as_secs_f64() * 1e3,
        faults: w.faults.len() as u64,
        grid,
        stream,
        peak_pending: w.eng.peak_pending() as u64,
        queue: opts.queue,
        collection: opts.collect,
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;

    #[test]
    fn tiny_http_experiment_completes() {
        let cfg = presets::quick_http(4, 60.0, 42);
        let r = run_experiment(&cfg);
        assert!(r.data.completed() > 50, "completed {}", r.data.completed());
        assert_eq!(r.data.dropped_unsynced, 0);
        assert!(r.events > 100);
        // conservation: service accounting matches
        let st = r.service_stats;
        assert!(st.submitted >= st.completed + st.denied + st.errored);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = presets::quick_http(3, 30.0, 7);
        let a = run_experiment(&cfg);
        let b = run_experiment(&cfg);
        assert_eq!(a.data.samples.len(), b.data.samples.len());
        assert_eq!(a.events, b.events);
        for (x, y) in a.data.samples.iter().zip(&b.data.samples) {
            assert_eq!(x.t_end, y.t_end);
            assert_eq!(x.rt, y.rt);
        }
    }

    #[test]
    fn queue_choice_does_not_perturb_the_run() {
        let cfg = presets::quick_http(3, 30.0, 7);
        let heap = run_experiment_opts(
            &cfg,
            RunOptions {
                queue: QueueKind::Heap,
                ..RunOptions::default()
            },
        );
        let wheel = run_experiment_opts(
            &cfg,
            RunOptions {
                queue: QueueKind::Wheel,
                ..RunOptions::default()
            },
        );
        assert_eq!(heap.events, wheel.events);
        assert_eq!(heap.data.samples.len(), wheel.data.samples.len());
        for (x, y) in heap.data.samples.iter().zip(&wheel.data.samples) {
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
            assert_eq!(x.outcome, y.outcome);
        }
    }

    #[test]
    fn map_layout_does_not_perturb_the_run() {
        let mut cfg = presets::quick_http(4, 60.0, 23);
        // hostile enough to exercise abandon paths (crash with an
        // in-flight request) under both layouts
        cfg.controller.silence_timeout_s = 30.0;
        cfg.scenario.timeline = vec![crate::scenario::ScenarioEvent {
            at_s: 20.0,
            action: crate::scenario::Action::CrashTesters {
                frac: 0.5,
                restart_after_s: Some(15.0),
            },
        }];
        let dense = run_experiment_opts(
            &cfg,
            RunOptions {
                map: MapKind::Dense,
                ..RunOptions::default()
            },
        );
        let hash = run_experiment_opts(
            &cfg,
            RunOptions {
                map: MapKind::Hash,
                ..RunOptions::default()
            },
        );
        assert_eq!(dense.events, hash.events);
        assert_eq!(dense.data.samples.len(), hash.data.samples.len());
        for (x, y) in dense.data.samples.iter().zip(&hash.data.samples) {
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
            assert_eq!(x.rt.to_bits(), y.rt.to_bits());
            assert_eq!(x.outcome, y.outcome);
            assert_eq!(
                x.t_end_true.to_bits(),
                y.t_end_true.to_bits(),
                "truth stores disagree"
            );
        }
    }

    #[test]
    fn req_table_ring_semantics() {
        let mut t = ReqTable::default();
        for id in 0..6u32 {
            t.insert(id, id * 10);
        }
        assert_eq!(t.get(3), Some(30));
        assert_eq!(t.remove(0), Some(0));
        assert_eq!(t.remove(0), None, "double remove");
        // interior removal leaves base pinned at the oldest live id
        assert_eq!(t.remove(2), Some(20));
        assert_eq!(t.get(2), None);
        assert_eq!(t.get(1), Some(10));
        // removing the pin advances base past the tombstones
        assert_eq!(t.remove(1), Some(10));
        assert_eq!(t.base, 3);
        assert_eq!(t.get(7), None, "beyond the ring");
        assert_eq!(t.remove(2), None, "below base");
        for id in [3u32, 4, 5] {
            assert_eq!(t.remove(id), Some(id * 10));
        }
        assert!(t.ring.is_empty());
        assert_eq!(t.base, 6);
        t.insert(6, 60);
        assert_eq!(t.get(6), Some(60));
    }

    #[test]
    fn streaming_collects_without_retaining() {
        let cfg = presets::quick_http(4, 60.0, 42);
        let retain = run_experiment(&cfg);
        let stream = run_experiment_opts(
            &cfg,
            RunOptions {
                collect: CollectionMode::Stream,
                ..RunOptions::default()
            },
        );
        assert_eq!(stream.events, retain.events, "same simulation");
        assert!(stream.data.samples.is_empty(), "nothing retained");
        let agg = stream.stream.as_ref().expect("aggregator present");
        // same sample population, counted instead of stored
        assert_eq!(
            agg.samples_seen + stream.data.dropped_unsynced,
            retain.data.samples.len() as u64 + retain.data.dropped_unsynced
        );
        assert_eq!(agg.binned.total_ok as usize, retain.data.completed());
        assert_eq!(stream.data.testers.len(), retain.data.testers.len());
        assert!(retain.stream.is_none());
        assert!(stream.peak_pending > 0);
    }

    #[test]
    fn samples_reconcile_close_to_truth() {
        let cfg = presets::quick_http(4, 60.0, 11);
        let r = run_experiment(&cfg);
        let mut errs: Vec<f64> = r
            .data
            .samples
            .iter()
            .filter(|s| s.t_end_true.is_finite())
            .map(|s| (s.t_end - s.t_end_true).abs())
            .collect();
        assert!(!errs.is_empty());
        errs.sort_by(f64::total_cmp);
        let med = errs[errs.len() / 2];
        // reconciliation error is clock-sync error: tens of ms, never s
        assert!(med < 0.25, "median reconciliation error {med}");
    }

    #[test]
    fn ramp_is_staggered() {
        let cfg = presets::quick_http(5, 60.0, 13);
        let r = run_experiment(&cfg);
        let starts: Vec<f64> =
            r.data.testers.iter().map(|t| t.started_at).collect();
        for w in starts.windows(2) {
            let gap = w[1] - w[0];
            assert!((gap - cfg.controller.stagger_s).abs() < 1e-6,
                "stagger gap {gap}");
        }
    }

    #[test]
    fn overlapping_weather_combines_and_clears_independently() {
        use crate::scenario::WeatherPatch;
        let partition = (1u64, WeatherPatch::partition());
        let lossy = (2u64, WeatherPatch::lossy(0.1));
        let spiky = (3u64, WeatherPatch::spike(4.0));
        let both = combine_weather(&[partition, lossy]);
        assert!(both.partitioned);
        assert_eq!(both.extra_loss, 0.1);
        // clearing the short lossy spell must not lift the partition
        let left = combine_weather(&[partition]);
        assert!(left.partitioned);
        let calm = combine_weather(&[lossy, spiky]);
        assert!(!calm.partitioned);
        assert_eq!(calm.latency_factor, 4.0);
        assert_eq!(calm.extra_loss, 0.1);
        assert!(combine_weather(&[]).is_clear());
    }

    #[test]
    fn scheduled_crash_and_restart_rejoin() {
        let mut cfg = presets::quick_http(4, 120.0, 23);
        cfg.controller.silence_timeout_s = 30.0;
        cfg.scenario.timeline = vec![crate::scenario::ScenarioEvent {
            at_s: 40.0,
            action: crate::scenario::Action::CrashTesters {
                frac: 1.0,
                restart_after_s: Some(60.0),
            },
        }];
        let r = run_experiment(&cfg);
        assert_eq!(r.faults, 8, "4 crashes + 4 restarts");
        let rejoins: u32 = r.data.testers.iter().map(|t| t.rejoins).sum();
        assert!(rejoins >= 3, "rejoins {rejoins}");
        // total outage: no completions while everyone is down...
        let during = r
            .data
            .samples
            .iter()
            .filter(|s| s.t_end > 45.0 && s.t_end < 95.0)
            .count();
        assert_eq!(during, 0, "samples during the outage");
        // ...and the pool resumes testing after the restart
        let after = r.data.samples.iter().filter(|s| s.t_end > 105.0).count();
        assert!(after > 0, "no samples after the restart");
    }

    #[test]
    fn service_restart_fails_in_flight_requests() {
        let mut cfg = presets::prews_small(8, 240.0, 29);
        cfg.scenario.timeline = vec![crate::scenario::ScenarioEvent {
            at_s: 150.0,
            action: crate::scenario::Action::RestartService,
        }];
        let r = run_experiment(&cfg);
        assert!(r.service_stats.errored >= 1, "restart must kill work");
        let errors = r
            .data
            .samples
            .iter()
            .filter(|s| s.outcome == crate::metrics::SampleOutcome::ServiceError)
            .count();
        assert!(errors >= 1, "testers must see the failures");
        let st = r.service_stats;
        assert!(st.submitted >= st.completed + st.denied + st.errored);
    }

    #[test]
    fn service_degradation_reduces_throughput() {
        let base = run_experiment(&presets::prews_small(8, 300.0, 31));
        let mut cfg = presets::prews_small(8, 300.0, 31);
        cfg.scenario.timeline = vec![crate::scenario::ScenarioEvent {
            at_s: 100.0,
            action: crate::scenario::Action::DegradeService {
                factor: 0.2,
                duration_s: 150.0,
            },
        }];
        let r = run_experiment(&cfg);
        assert!(
            r.data.completed() < base.data.completed(),
            "5x slower CPU for half the run must cost completions \
             ({} vs {})",
            r.data.completed(),
            base.data.completed()
        );
    }

    #[test]
    fn nested_degradation_inner_restore_does_not_lift_outer() {
        // worst-wins: adding a milder inner degradation inside a harsher
        // outer window must not change the run at all — in particular
        // the inner restore must not lift the outer degradation early
        let mk = |with_inner: bool| {
            let mut cfg = presets::prews_small(6, 300.0, 41);
            let mut tl = vec![crate::scenario::ScenarioEvent {
                at_s: 100.0,
                action: crate::scenario::Action::DegradeService {
                    factor: 0.2,
                    duration_s: 150.0,
                },
            }];
            if with_inner {
                tl.push(crate::scenario::ScenarioEvent {
                    at_s: 130.0,
                    action: crate::scenario::Action::DegradeService {
                        factor: 0.5,
                        duration_s: 40.0,
                    },
                });
            }
            cfg.scenario.timeline = tl;
            run_experiment(&cfg)
        };
        let a = mk(false);
        let b = mk(true);
        assert_eq!(a.data.samples.len(), b.data.samples.len());
        for (x, y) in a.data.samples.iter().zip(&b.data.samples) {
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
            assert_eq!(x.outcome, y.outcome);
        }
    }

    #[test]
    fn partition_weather_starves_then_recovers() {
        let mut cfg = presets::quick_http(3, 120.0, 37);
        cfg.scenario.timeline = vec![crate::scenario::ScenarioEvent {
            at_s: 40.0,
            action: crate::scenario::Action::Weather {
                frac: 1.0,
                patch: crate::scenario::WeatherPatch::partition(),
                duration_s: 30.0,
            },
        }];
        let r = run_experiment(&cfg);
        // requests and responses are all lost during the partition, so
        // every invocation in the window times out (timeout 30 s)
        let during_ok = r
            .data
            .samples
            .iter()
            .filter(|s| s.outcome.ok() && s.t_end > 41.0 && s.t_end < 69.0)
            .count();
        assert_eq!(during_ok, 0, "completions inside the partition");
        let after_ok = r
            .data
            .samples
            .iter()
            .filter(|s| s.outcome.ok() && s.t_end > 80.0)
            .count();
        assert!(after_ok > 0, "no recovery after the partition lifted");
    }

    #[test]
    fn dropped_session_stops_tester_even_when_stop_is_lost() {
        // §3 regression: the controller evicts a partitioned-but-alive
        // tester for silence; the Stop message is lost inside the
        // partition.  The tester must still stop issuing clients the
        // moment it *discovers* the dead session — its first delivered
        // write after the partition heals — instead of testing
        // unmonitored until the next Hello re-registers it.
        let mut cfg = presets::quick_http(1, 120.0, 19);
        cfg.controller.silence_timeout_s = 15.0;
        cfg.scenario.timeline = vec![crate::scenario::ScenarioEvent {
            // heal at t=61, just after the t=60 liveness tick, so the
            // tester's next delivered frame is a sample, not a Hello
            at_s: 10.0,
            action: crate::scenario::Action::Weather {
                frac: 1.0,
                patch: crate::scenario::WeatherPatch::partition(),
                duration_s: 51.0,
            },
        }];
        let r = run_experiment(&cfg);
        let t = &r.data.testers[0];
        assert!(t.evicted, "silence eviction must have fired");
        assert_eq!(t.rejoins, 0, "a reset session must not auto-rejoin");
        let late = r.data.samples.iter().filter(|s| s.t_end > 90.0).count();
        assert_eq!(
            late, 0,
            "tester kept loading the service after its session dropped"
        );
    }

    #[test]
    fn sync_happens_repeatedly() {
        let mut cfg = presets::quick_http(2, 120.0, 17);
        cfg.controller.desc.sync_interval_s = 30.0;
        let r = run_experiment(&cfg);
        for t in &r.data.testers {
            // 120 s / 30 s -> at least 3 sync points per tester
            assert!(t.clock.len() >= 3, "sync points {}", t.clock.len());
        }
        assert!(r.sync.errors_s.len() >= 6);
    }
}
