//! The experiment world: wires controller, testers, clock sync, the WAN
//! and a target service into the discrete-event engine and runs a full
//! DiPerF experiment.
//!
//! This is the simulation twin of the paper's deployment: one controller
//! machine, one target-service machine and one time-stamp server on the
//! "UofC" LAN, plus N wide-area tester nodes.  Every protocol action —
//! client-code distribution, staggered tester starts, each client's RPC,
//! the five-minute sync exchanges, sample streaming, failure detection —
//! is an explicit event with network latency applied, so framework
//! artifacts (sync error, report latency, ramp shape) appear in the data
//! exactly as they did on PlanetLab.

pub mod presets;

use std::collections::HashMap;

use crate::client;
use crate::cluster::{Testbed, TestbedParams};
use crate::controller::{Controller, ControllerConfig, CtrlAction};
use crate::ids::{RequestId, TesterId};
use crate::metrics::RunData;
use crate::net::NetModel;
use crate::services::{
    gram_prews::{GramPrews, GramPrewsParams},
    gram_ws::{GramWs, GramWsParams},
    http::{HttpParams, HttpService},
    Service, ServiceStats, SvcOut,
};
use crate::sim::{Engine, SimDuration, SimTime};
use crate::tester::{Phase, Tester};
use crate::timesync::{SyncAccuracy, SyncPoint};
use crate::transport::{
    ClientCode, CtrlMsg, GoodbyeReason, TesterMsg,
};
use crate::util::Pcg64;

/// Which target service to deploy (with calibration).
#[derive(Clone, Debug)]
pub enum ServiceKind {
    /// GT3.2 pre-WS GRAM model.
    GramPrews(GramPrewsParams),
    /// GT3.2 WS GRAM model.
    GramWs(GramWsParams),
    /// Apache + CGI model.
    Http(HttpParams),
}

impl ServiceKind {
    fn build(&self, speed: f64) -> Box<dyn Service> {
        match self {
            ServiceKind::GramPrews(p) => {
                let mut p = p.clone();
                p.speed = speed;
                Box::new(GramPrews::new(p))
            }
            ServiceKind::GramWs(p) => {
                let mut p = p.clone();
                p.speed = speed;
                Box::new(GramWs::new(p))
            }
            ServiceKind::Http(p) => {
                let mut p = p.clone();
                p.speed = speed;
                Box::new(HttpService::new(p))
            }
        }
    }

    /// Service label (for reports).
    pub fn label(&self) -> &'static str {
        match self {
            ServiceKind::GramPrews(_) => "gt3.2-prews-gram",
            ServiceKind::GramWs(_) => "gt3.2-ws-gram",
            ServiceKind::Http(_) => "apache-cgi",
        }
    }
}

/// Full experiment specification.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Master seed; every component stream derives from it.
    pub seed: u64,
    /// Target service + calibration.
    pub service: ServiceKind,
    /// Testbed synthesis parameters (tester count lives here).
    pub testbed: TestbedParams,
    /// Controller policy (stagger, eviction, test description).
    pub controller: ControllerConfig,
    /// Client-code payload for the deploy phase.
    pub code: ClientCode,
    /// Extra time after the last tester's duration before the
    /// experiment is cut off.
    pub grace_s: f64,
}

/// Everything a finished experiment produces.
pub struct ExperimentResult {
    /// Reconciled samples + per-tester records.
    pub data: RunData,
    /// Service-side counters.
    pub service_stats: ServiceStats,
    /// Service label.
    pub service_name: &'static str,
    /// Clock-sync accuracy over all sync exchanges (vs simulation truth).
    pub sync: SyncAccuracy,
    /// DES events dispatched.
    pub events: u64,
    /// Wall-clock milliseconds spent simulating.
    pub wall_ms: f64,
    /// Service stalls observed (WS GRAM only; 0 otherwise).
    pub stalls: u64,
}

/// Events of the DiPerF world.
enum Ev {
    /// scp of the client code to tester `i` completed.
    DeployDone(usize),
    /// Controller message delivered at tester `i`.
    CtrlDeliver(usize, CtrlMsg),
    /// Tester report delivered at the controller.
    TesterDeliver(usize, TesterMsg),
    /// Controller decides to start tester `i` (per the ramp schedule).
    StartTester(usize),
    /// Tester `i` launches its next client.
    ClientLaunch(usize),
    /// A client's request reaches the service.
    RequestArrive(RequestId),
    /// A service wake (PS completion horizon) fires; the tag must match
    /// the world's armed wake or the event is stale and skipped.
    ServiceWake(u64),
    /// The service's response for `req` reaches its tester.
    ResponseDeliver(RequestId, crate::services::Outcome),
    /// Periodic tester-timeout sweep (§3 failure #1).  One recurring
    /// event replaces a per-launch timeout event: stale timeouts used to
    /// sit in the heap for the full timeout window and dominated heap
    /// traffic (see EXPERIMENTS.md §Perf).
    TimeoutSweep,
    /// Tester `i`'s sync request reaches the time server.
    SyncReqArrive(usize, f64),
    /// The sync reply reaches tester `i` (server reading attached).
    SyncReplyArrive(usize, f64, f64),
    /// Tester `i` begins its next sync exchange.
    SyncBegin(usize),
    /// Node under tester `i` dies.
    NodeFail(usize),
    /// Controller liveness sweep.
    CtrlTick,
}

struct ReqInfo {
    tester: usize,
}

/// The running world.
struct World {
    eng: Engine<Ev>,
    bed: Testbed,
    net: NetModel,
    controller: Controller,
    testers: Vec<Tester>,
    service: Box<dyn Service>,
    /// Per-component RNG streams (deterministic regardless of order).
    rng_net: Pcg64,
    rng_svc: Pcg64,
    rng_testers: Vec<Pcg64>,
    reqs: HashMap<u32, ReqInfo>,
    next_req: u32,
    /// Simulation truth for validation: (tester, seq) -> true end time.
    truth: HashMap<(u32, u32), f64>,
    sync: SyncAccuracy,
    deploys_pending: usize,
    ramp_begun: bool,
    horizon: SimTime,
    /// The earliest armed service wake (dedupe: stale ServiceWake events
    /// whose tag mismatches are dropped, so wake chains cannot multiply).
    svc_wake: Option<u64>,
}

impl World {
    fn local(&self, i: usize) -> f64 {
        self.bed
            .node(self.testers[i].node)
            .clock
            .local_secs(self.eng.now())
    }

    /// Convert a tester-local target time to global for scheduling.
    fn local_to_global(&self, i: usize, local: f64) -> SimTime {
        let g = self
            .bed
            .node(self.testers[i].node)
            .clock
            .global_secs(local);
        SimTime::from_secs_f64(g.max(self.eng.now().as_secs_f64()))
    }

    fn send_to_controller(&mut self, i: usize, msg: TesterMsg) {
        if self.testers[i].phase == Phase::Dead {
            return;
        }
        let lat = self.net.latency(
            self.testers[i].node,
            self.bed.controller,
            &mut self.rng_net,
        );
        self.eng.schedule_in(lat, Ev::TesterDeliver(i, msg));
    }

    fn send_to_tester(&mut self, i: usize, msg: CtrlMsg) {
        let lat = self.net.latency(
            self.bed.controller,
            self.testers[i].node,
            &mut self.rng_net,
        );
        self.eng.schedule_in(lat, Ev::CtrlDeliver(i, msg));
    }

    fn handle_svc_outs(&mut self, outs: Vec<SvcOut>) {
        for o in outs {
            match o {
                SvcOut::Wake { at } => {
                    let tag = at.as_micros().max(self.eng.now().as_micros());
                    if self.svc_wake.is_none_or(|w| tag < w) {
                        self.svc_wake = Some(tag);
                        self.eng
                            .schedule(SimTime(tag), Ev::ServiceWake(tag));
                    }
                }
                SvcOut::Done { req, outcome, .. } => {
                    if let Some(info) = self.reqs.get(&req.0) {
                        let lat = self.net.latency(
                            self.bed.service,
                            self.testers[info.tester].node,
                            &mut self.rng_net,
                        );
                        self.eng
                            .schedule_in(lat, Ev::ResponseDeliver(req, outcome));
                    }
                }
            }
        }
    }

    /// Schedule tester `i`'s next client launch (local pacing -> global).
    fn schedule_next_launch(&mut self, i: usize) {
        let now_local = self.local(i);
        let t = self.testers[i].next_launch_local(now_local);
        let at = self.local_to_global(i, t);
        self.eng.schedule(at, Ev::ClientLaunch(i));
    }

    /// Tester produced a sample: forward it, apply the give-up policy,
    /// and keep the loop going.
    fn after_sample(&mut self, i: usize, sample: crate::metrics::CallSample) {
        self.truth.insert(
            (sample.tester.0, sample.seq),
            self.eng.now().as_secs_f64(),
        );
        self.send_to_controller(i, TesterMsg::Sample(sample));
        let give_up = self.testers[i].desc.give_up_failures;
        if self.testers[i].should_give_up(give_up) {
            self.testers[i].stop();
            self.send_to_controller(
                i,
                TesterMsg::Goodbye(GoodbyeReason::TooManyFailures),
            );
            return;
        }
        if self.testers[i].phase == Phase::Running {
            if self.testers[i].duration_elapsed(self.local(i)) {
                self.testers[i].stop();
                self.send_to_controller(
                    i,
                    TesterMsg::Goodbye(GoodbyeReason::Finished),
                );
            } else {
                self.schedule_next_launch(i);
            }
        }
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::DeployDone(i) => {
                self.controller.deploy_finished(
                    TesterId(i as u32),
                    true,
                    self.eng.now().as_secs_f64(),
                );
                self.deploys_pending -= 1;
                if self.deploys_pending == 0 && !self.ramp_begun {
                    self.ramp_begun = true;
                    let ramp0 = self.eng.now().as_secs_f64();
                    for j in 0..self.testers.len() {
                        let at = SimTime::from_secs_f64(
                            self.controller.start_time(j, ramp0),
                        );
                        self.eng.schedule(at, Ev::StartTester(j));
                    }
                    // horizon: last start + duration + grace
                    let last = self
                        .controller
                        .start_time(self.testers.len() - 1, ramp0);
                    self.horizon = SimTime::from_secs_f64(
                        last + self.controller.description().duration_s
                            + 120.0,
                    );
                }
            }
            Ev::StartTester(i) => {
                self.controller
                    .mark_started(TesterId(i as u32), self.eng.now().as_secs_f64());
                self.send_to_tester(i, CtrlMsg::Start(self.controller.description()));
            }
            Ev::CtrlDeliver(i, msg) => match msg {
                CtrlMsg::Start(desc) => {
                    if self.testers[i].phase != Phase::Idle {
                        return;
                    }
                    let now_local = self.local(i);
                    self.testers[i].start(now_local, desc);
                    // latency estimate: one ping round trip to the service
                    let rtt = self
                        .net
                        .latency(
                            self.testers[i].node,
                            self.bed.service,
                            &mut self.rng_net,
                        )
                        .as_secs_f64()
                        + self
                            .net
                            .latency(
                                self.bed.service,
                                self.testers[i].node,
                                &mut self.rng_net,
                            )
                            .as_secs_f64();
                    self.testers[i].latency_estimate_s = rtt / 2.0;
                    // first sync now; first client launch follows it
                    self.eng.schedule_in(SimDuration(0), Ev::SyncBegin(i));
                }
                CtrlMsg::Stop => {
                    self.testers[i].stop();
                }
            },
            Ev::SyncBegin(i) => {
                if !matches!(self.testers[i].phase, Phase::Running) {
                    return;
                }
                let l1 = self.local(i);
                let lat = self.net.latency(
                    self.testers[i].node,
                    self.bed.time_server,
                    &mut self.rng_net,
                );
                self.eng.schedule_in(lat, Ev::SyncReqArrive(i, l1));
            }
            Ev::SyncReqArrive(i, l1) => {
                // the server stamps its own clock reading
                let server = self
                    .bed
                    .node(self.bed.time_server)
                    .clock
                    .local_secs(self.eng.now());
                let lat = self.net.latency(
                    self.bed.time_server,
                    self.testers[i].node,
                    &mut self.rng_net,
                );
                self.eng
                    .schedule_in(lat, Ev::SyncReplyArrive(i, l1, server));
            }
            Ev::SyncReplyArrive(i, l1, server) => {
                if self.testers[i].phase == Phase::Dead {
                    return;
                }
                let l2 = self.local(i);
                let p = SyncPoint { l1, server, l2 };
                let first = self.testers[i].clock.is_empty();
                self.testers[i].record_sync(p);
                // accuracy vs simulation truth, at the reply instant
                if let Some(est) = self.testers[i].clock.to_global(l2) {
                    let truth = self.eng.now().as_secs_f64();
                    self.sync.push(est - truth, p.rtt());
                }
                self.send_to_controller(i, TesterMsg::Sync(p));
                if self.testers[i].phase == Phase::Running {
                    // periodic re-sync
                    let next_local = l2 + self.testers[i].desc.sync_interval_s;
                    let at = self.local_to_global(i, next_local);
                    self.eng.schedule(at, Ev::SyncBegin(i));
                    if first {
                        self.schedule_next_launch(i);
                    }
                }
            }
            Ev::ClientLaunch(i) => {
                if !self.testers[i].can_launch(self.local(i)) {
                    // duration elapsed or a client is still outstanding
                    if self.testers[i].phase == Phase::Running
                        && self.testers[i].outstanding.is_none()
                        && self.testers[i].duration_elapsed(self.local(i))
                    {
                        self.testers[i].stop();
                        self.send_to_controller(
                            i,
                            TesterMsg::Goodbye(GoodbyeReason::Finished),
                        );
                    }
                    return;
                }
                let now_local = self.local(i);
                let node = self.bed.node(self.testers[i].node).clone();
                if !client::try_start(
                    node.client_start_failure,
                    &mut self.rng_testers[i],
                ) {
                    let s = self.testers[i].record_start_failure(now_local);
                    self.after_sample(i, s);
                    return;
                }
                let req = RequestId(self.next_req);
                self.next_req += 1;
                let inv = self.testers[i].launch(now_local, req);
                self.reqs.insert(req.0, ReqInfo { tester: i });
                // client exec overhead before the RPC leaves the node
                let pre =
                    client::exec_overhead_s(node.cpu_speed, &mut self.rng_testers[i]);
                let lat = self.net.latency(
                    self.testers[i].node,
                    self.bed.service,
                    &mut self.rng_net,
                );
                self.eng.schedule_in(
                    SimDuration::from_secs_f64(pre) + lat,
                    Ev::RequestArrive(req),
                );
                let _ = inv; // timeout handled by the periodic sweep
            }
            Ev::RequestArrive(req) => {
                let client_id = match self.reqs.get(&req.0) {
                    Some(info) => info.tester as u32,
                    None => return,
                };
                let outs = self.service.submit(
                    self.eng.now(),
                    req,
                    client_id,
                    &mut self.rng_svc,
                );
                self.handle_svc_outs(outs);
            }
            Ev::ServiceWake(tag) => {
                if self.svc_wake != Some(tag) {
                    return; // superseded by an earlier wake
                }
                self.svc_wake = None;
                let outs = self.service.on_wake(self.eng.now(), &mut self.rng_svc);
                self.handle_svc_outs(outs);
            }
            Ev::ResponseDeliver(req, outcome) => {
                let Some(info) = self.reqs.remove(&req.0) else {
                    return;
                };
                let i = info.tester;
                if self.testers[i].phase == Phase::Dead {
                    return;
                }
                let now_local = self.local(i);
                let node = self.bed.node(self.testers[i].node).clone();
                let post =
                    client::exec_overhead_s(node.cpu_speed, &mut self.rng_testers[i]);
                if let Some(s) = self.testers[i].record_result(
                    now_local,
                    req,
                    client::classify(outcome),
                    post,
                ) {
                    self.after_sample(i, s);
                }
            }
            Ev::TimeoutSweep => {
                for i in 0..self.testers.len() {
                    if self.testers[i].phase == Phase::Dead {
                        continue;
                    }
                    let Some(inv) = self.testers[i].outstanding else {
                        continue;
                    };
                    let now_local = self.local(i);
                    if now_local - inv.launched_local
                        < self.testers[i].desc.timeout_s
                    {
                        continue;
                    }
                    if let Some(s) = self.testers[i]
                        .record_timeout(now_local, inv.timeout_token)
                    {
                        // the request's eventual response must be ignored
                        self.reqs.remove(&inv.req.0);
                        self.after_sample(i, s);
                    }
                }
                self.eng
                    .schedule_in(SimDuration::from_secs(5), Ev::TimeoutSweep);
            }
            Ev::TesterDeliver(i, msg) => {
                let action = self.controller.on_msg(
                    self.eng.now().as_secs_f64(),
                    TesterId(i as u32),
                    msg,
                );
                if let Some(CtrlAction::Evict(t)) = action {
                    self.send_to_tester(t.index(), CtrlMsg::Stop);
                }
            }
            Ev::NodeFail(i) => {
                self.testers[i].kill();
            }
            Ev::CtrlTick => {
                let now = self.eng.now().as_secs_f64();
                for a in self.controller.check_liveness(now) {
                    let CtrlAction::Evict(t) = a;
                    self.send_to_tester(t.index(), CtrlMsg::Stop);
                }
                self.eng
                    .schedule_in(SimDuration::from_secs(30), Ev::CtrlTick);
            }
        }
    }
}

/// Run a complete DiPerF experiment.
pub fn run_experiment(cfg: &ExperimentConfig) -> ExperimentResult {
    let wall = std::time::Instant::now();
    let mut root = Pcg64::seed_from(cfg.seed);
    let mut rng_bed = root.split(1);
    let bed = Testbed::generate(&cfg.testbed, &mut rng_bed);
    let n = bed.testers.len();

    let service = cfg
        .service
        .build(bed.node(bed.service).cpu_speed);
    let controller = Controller::new(cfg.controller.clone(), &bed.testers);
    let testers: Vec<Tester> = bed
        .testers
        .iter()
        .enumerate()
        .map(|(i, &node)| Tester::new(TesterId(i as u32), node))
        .collect();
    let rng_testers: Vec<Pcg64> =
        (0..n).map(|i| root.split(100 + i as u64)).collect();

    let mut w = World {
        eng: Engine::new(),
        net: bed.net.clone(),
        controller,
        testers,
        service,
        rng_net: root.split(2),
        rng_svc: root.split(3),
        rng_testers,
        reqs: HashMap::new(),
        next_req: 0,
        truth: HashMap::new(),
        sync: SyncAccuracy::new(),
        deploys_pending: n,
        ramp_begun: false,
        horizon: SimTime::MAX,
        svc_wake: None,
        bed,
    };

    // deploy phase: scp the client code to every tester node
    let mut rng_deploy = root.split(4);
    for i in 0..n {
        let dt = w.net.transfer_time(
            w.bed.controller,
            w.testers[i].node,
            cfg.code.bytes(),
            &mut rng_deploy,
        );
        w.eng.schedule(SimTime(0) + dt, Ev::DeployDone(i));
    }
    // node-failure injection
    let duration =
        SimDuration::from_secs_f64(cfg.controller.desc.duration_s * 2.0);
    let mut rng_fail = root.split(5);
    for i in 0..n {
        if let Some(at) =
            w.bed
                .sample_failure_time(w.testers[i].node, duration, &mut rng_fail)
        {
            w.eng.schedule(at, Ev::NodeFail(i));
        }
    }
    w.eng.schedule(SimTime(0), Ev::CtrlTick);
    w.eng.schedule(SimTime(0), Ev::TimeoutSweep);

    // main loop (horizon is set once the ramp schedule is known)
    loop {
        let horizon = w.horizon
            + SimDuration::from_secs_f64(cfg.grace_s.max(0.0));
        let Some((_, ev)) = ({
            if w.eng.pending() == 0 || w.eng.now() > horizon {
                None
            } else {
                w.eng.next()
            }
        }) else {
            break;
        };
        w.handle(ev);
    }

    let duration_s = w.eng.now().as_secs_f64();
    let mut data = w.controller.finalize(duration_s);
    // backfill simulation truth for sync-pipeline validation
    for s in data.samples.iter_mut() {
        s.t_end_true = w
            .truth
            .get(&(s.tester.0, s.seq))
            .copied()
            .unwrap_or(f64::NAN);
    }

    ExperimentResult {
        data,
        service_stats: w.service.stats(),
        service_name: w.service.name(),
        stalls: w.service.stalls(),
        sync: w.sync,
        events: w.eng.processed(),
        wall_ms: wall.elapsed().as_secs_f64() * 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;

    #[test]
    fn tiny_http_experiment_completes() {
        let cfg = presets::quick_http(4, 60.0, 42);
        let r = run_experiment(&cfg);
        assert!(r.data.completed() > 50, "completed {}", r.data.completed());
        assert_eq!(r.data.dropped_unsynced, 0);
        assert!(r.events > 100);
        // conservation: service accounting matches
        let st = r.service_stats;
        assert!(st.submitted >= st.completed + st.denied + st.errored);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = presets::quick_http(3, 30.0, 7);
        let a = run_experiment(&cfg);
        let b = run_experiment(&cfg);
        assert_eq!(a.data.samples.len(), b.data.samples.len());
        assert_eq!(a.events, b.events);
        for (x, y) in a.data.samples.iter().zip(&b.data.samples) {
            assert_eq!(x.t_end, y.t_end);
            assert_eq!(x.rt, y.rt);
        }
    }

    #[test]
    fn samples_reconcile_close_to_truth() {
        let cfg = presets::quick_http(4, 60.0, 11);
        let r = run_experiment(&cfg);
        let mut errs: Vec<f64> = r
            .data
            .samples
            .iter()
            .filter(|s| s.t_end_true.is_finite())
            .map(|s| (s.t_end - s.t_end_true).abs())
            .collect();
        assert!(!errs.is_empty());
        errs.sort_by(f64::total_cmp);
        let med = errs[errs.len() / 2];
        // reconciliation error is clock-sync error: tens of ms, never s
        assert!(med < 0.25, "median reconciliation error {med}");
    }

    #[test]
    fn ramp_is_staggered() {
        let cfg = presets::quick_http(5, 60.0, 13);
        let r = run_experiment(&cfg);
        let starts: Vec<f64> =
            r.data.testers.iter().map(|t| t.started_at).collect();
        for w in starts.windows(2) {
            let gap = w[1] - w[0];
            assert!((gap - cfg.controller.stagger_s).abs() < 1e-6,
                "stagger gap {gap}");
        }
    }

    #[test]
    fn sync_happens_repeatedly() {
        let mut cfg = presets::quick_http(2, 120.0, 17);
        cfg.controller.desc.sync_interval_s = 30.0;
        let r = run_experiment(&cfg);
        for t in &r.data.testers {
            // 120 s / 30 s -> at least 3 sync points per tester
            assert!(t.clock.len() >= 3, "sync points {}", t.clock.len());
        }
        assert!(r.sync.errors_s.len() >= 6);
    }
}
