//! Client-invocation model.
//!
//! In DiPerF "clients are full blown executables that make one RPC-like
//! call to the service" (§3) — the most generic tester/client interface.
//! This module models one such invocation: its local start (which can
//! fail, §3 failure #2), the RPC round trip (timed by the tester), and
//! the response-time adjustment the paper applies (§4: response time is
//! the wall span "minus the network latency and minus the execution time
//! of the client code").

use crate::ids::RequestId;
use crate::metrics::SampleOutcome;
use crate::services::Outcome;
use crate::util::Pcg64;

/// One in-flight client invocation, tracked by its tester.
#[derive(Clone, Copy, Debug)]
pub struct Invocation {
    /// The request this client issued.
    pub req: RequestId,
    /// Per-tester sequence number.
    pub seq: u32,
    /// Tester-local launch time (s).
    pub launched_local: f64,
    /// Token matching the timeout event armed for this invocation
    /// (stale timeouts are ignored by comparing tokens).
    pub timeout_token: u64,
}

/// Local client start: fails with the node's start-failure probability
/// (out-of-memory class problems on the client machine).
pub fn try_start(start_failure_prob: f64, rng: &mut Pcg64) -> bool {
    !rng.chance(start_failure_prob)
}

/// Client-code execution overhead around the RPC (fork/exec, parsing),
/// in local seconds — scaled by the node's CPU speed.
pub fn exec_overhead_s(cpu_speed: f64, rng: &mut Pcg64) -> f64 {
    debug_assert!(cpu_speed > 0.0);
    crate::util::dist::lognormal_median(rng, 0.008, 1.3) / cpu_speed
}

/// The paper's response-time adjustment: wall span minus the tester's
/// network-latency estimate minus client execution time, floored at 0.
pub fn adjusted_rt(span_s: f64, latency_estimate_s: f64, exec_s: f64) -> f64 {
    (span_s - latency_estimate_s - exec_s).max(0.0)
}

/// Map a service outcome (carried back in the RPC response) to the
/// sample taxonomy.
pub fn classify(service_outcome: Outcome) -> SampleOutcome {
    match service_outcome {
        Outcome::Success => SampleOutcome::Success,
        Outcome::Denied => SampleOutcome::Denied,
        Outcome::Error => SampleOutcome::ServiceError,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_failure_probability() {
        let mut rng = Pcg64::seed_from(1);
        let fails = (0..10_000)
            .filter(|_| !try_start(0.1, &mut rng))
            .count();
        assert!((800..1200).contains(&fails), "fails {fails}");
        assert!(try_start(0.0, &mut rng));
    }

    #[test]
    fn adjusted_rt_subtracts_and_floors() {
        assert!((adjusted_rt(1.0, 0.2, 0.05) - 0.75).abs() < 1e-12);
        assert_eq!(adjusted_rt(0.1, 0.2, 0.05), 0.0);
    }

    #[test]
    fn classification() {
        assert_eq!(classify(Outcome::Success), SampleOutcome::Success);
        assert_eq!(classify(Outcome::Denied), SampleOutcome::Denied);
        assert_eq!(classify(Outcome::Error), SampleOutcome::ServiceError);
    }

    #[test]
    fn exec_overhead_scales_with_cpu() {
        let mut rng = Pcg64::seed_from(2);
        let fast: f64 = (0..2000).map(|_| exec_overhead_s(2.0, &mut rng)).sum();
        let mut rng = Pcg64::seed_from(2);
        let slow: f64 = (0..2000).map(|_| exec_overhead_s(0.5, &mut rng)).sum();
        assert!((slow / fast - 4.0).abs() < 1e-9);
    }
}
