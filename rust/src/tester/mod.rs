//! Tester agent (§3): runs clients against the target service, times
//! every call, syncs its clock every five minutes, streams samples to
//! the controller, and stops the moment its controller session dies.
//!
//! The state machine here is *pure* — it never touches the event queue.
//! The experiment world calls these methods at the right virtual times
//! and turns the returned values into events; that separation is what
//! makes the tester logic unit-testable without a simulation around it.

use crate::client::Invocation;
use crate::ids::{NodeId, RequestId, TesterId};
use crate::metrics::{CallSample, SampleOutcome};
use crate::timesync::{ClockMap, SyncPoint};
use crate::transport::TestDescription;

/// Lifecycle phase of a tester.
#[derive(Clone, Copy, Debug, Eq, PartialEq)]
pub enum Phase {
    /// Deployed, not yet started.
    Idle,
    /// Running clients.
    Running,
    /// Duration elapsed or Stop received; no new clients.
    Stopped,
    /// Node died; the agent is silent.
    Dead,
}

/// The tester agent's state.
#[derive(Clone, Debug)]
pub struct Tester {
    /// Identity (index into the controller roster).
    pub id: TesterId,
    /// Host node.
    pub node: NodeId,
    /// Current phase.
    pub phase: Phase,
    /// Active test description (valid once started).
    pub desc: TestDescription,
    /// Local time the test started.
    pub started_local: f64,
    /// Next client sequence number.
    pub seq: u32,
    /// The single outstanding invocation, if any (clients run
    /// sequentially: each is one RPC call).
    pub outstanding: Option<Invocation>,
    /// Tester-side clock map (mirror of what the controller builds).
    pub clock: ClockMap,
    /// Estimated one-way latency to the service (for the §4 response-
    /// time adjustment), measured by a ping at startup.
    pub latency_estimate_s: f64,
    /// Local time of the last client launch (for interval pacing).
    pub last_launch_local: f64,
    /// Consecutive failed invocations (drives the eviction policy).
    pub consecutive_failures: u32,
    /// Generation of the periodic clock-sync chain; stale chain events
    /// (from before a crash/restart) compare unequal and die out.
    pub sync_gen: u32,
    /// Crashes this agent has survived (scenario churn bookkeeping).
    pub crashes: u32,
    /// Phase at the moment of the last crash (restored on revive).
    prev_phase: Phase,
    /// Monotone token source for timeout events.
    next_token: u64,
}

impl Tester {
    /// A fresh, idle tester.
    pub fn new(id: TesterId, node: NodeId) -> Tester {
        Tester {
            id,
            node,
            phase: Phase::Idle,
            desc: TestDescription::default(),
            started_local: 0.0,
            seq: 0,
            outstanding: None,
            clock: ClockMap::new(),
            latency_estimate_s: 0.0,
            last_launch_local: f64::NEG_INFINITY,
            consecutive_failures: 0,
            sync_gen: 0,
            crashes: 0,
            prev_phase: Phase::Idle,
            next_token: 0,
        }
    }

    /// Controller's Start arrived (at local time `now_local`).
    pub fn start(&mut self, now_local: f64, desc: TestDescription) {
        debug_assert_eq!(self.phase, Phase::Idle);
        self.phase = Phase::Running;
        self.desc = desc;
        self.started_local = now_local;
        self.sync_gen += 1;
    }

    /// Stop (duration elapsed, Stop message, or session loss).
    pub fn stop(&mut self) {
        if self.phase != Phase::Dead {
            self.phase = Phase::Stopped;
        }
        self.outstanding = None;
    }

    /// The controller session died under the agent (TCP reset, ssh
    /// channel teardown).  Per §3 an unmonitored client must never load
    /// the service, so the tester stops issuing clients *immediately* —
    /// not at the next sync point or duration check.  The in-flight
    /// invocation (if any) is abandoned unreported: nobody is listening.
    pub fn session_lost(&mut self) {
        self.stop();
    }

    /// The node died under the agent.
    pub fn kill(&mut self) {
        if self.phase != Phase::Dead {
            self.prev_phase = self.phase;
            self.crashes += 1;
        }
        self.phase = Phase::Dead;
        self.outstanding = None;
    }

    /// The node came back: the agent restarts in the phase it crashed
    /// in, with fresh invocation/failure state (its clock map survives —
    /// skew and drift are properties of the hardware, not the process).
    /// Returns the phase after revival; a no-op if the agent was not
    /// dead.
    pub fn revive(&mut self) -> Phase {
        if self.phase == Phase::Dead {
            self.phase = self.prev_phase;
            self.outstanding = None;
            self.consecutive_failures = 0;
            self.sync_gen += 1;
        }
        self.phase
    }

    /// Has the configured test duration elapsed?
    pub fn duration_elapsed(&self, now_local: f64) -> bool {
        now_local - self.started_local >= self.desc.duration_s
    }

    /// Earliest local time the next client may launch: the configured
    /// interval after the previous launch, but never before `now`
    /// (back-to-back when the previous client ran long — §4).
    pub fn next_launch_local(&self, now_local: f64) -> f64 {
        let spacing = self.desc.min_spacing_s();
        now_local.max(self.last_launch_local + spacing)
    }

    /// Ready to launch? (running, nothing outstanding)
    pub fn can_launch(&self, now_local: f64) -> bool {
        self.phase == Phase::Running
            && self.outstanding.is_none()
            && !self.duration_elapsed(now_local)
    }

    /// Launch a client at `now_local` issuing request `req`.
    pub fn launch(&mut self, now_local: f64, req: RequestId) -> Invocation {
        debug_assert!(self.can_launch(now_local));
        let inv = Invocation {
            req,
            seq: self.seq,
            launched_local: now_local,
            timeout_token: self.next_token,
        };
        self.next_token += 1;
        self.seq += 1;
        self.last_launch_local = now_local;
        self.outstanding = Some(inv);
        inv
    }

    /// Record a locally-failed start (§3 failure #2): emits the sample
    /// without any RPC having been issued.
    pub fn record_start_failure(&mut self, now_local: f64) -> CallSample {
        let seq = self.seq;
        self.seq += 1;
        self.last_launch_local = now_local;
        self.consecutive_failures += 1;
        CallSample {
            tester: self.id,
            seq,
            t_submit_local: now_local,
            t_done_local: now_local,
            rt_s: 0.0,
            outcome: SampleOutcome::StartFailure,
        }
    }

    /// The outstanding invocation finished (response arrived) at local
    /// time `now_local` with the given outcome; returns the sample.
    /// Returns `None` for stale responses (already timed out).
    pub fn record_result(
        &mut self,
        now_local: f64,
        req: RequestId,
        outcome: SampleOutcome,
        exec_overhead_s: f64,
    ) -> Option<CallSample> {
        let inv = self.outstanding?;
        if inv.req != req {
            return None; // response for a timed-out predecessor
        }
        self.outstanding = None;
        let span = now_local - inv.launched_local;
        let rt = crate::client::adjusted_rt(
            span,
            2.0 * self.latency_estimate_s,
            exec_overhead_s,
        );
        if outcome.ok() {
            self.consecutive_failures = 0;
        } else {
            self.consecutive_failures += 1;
        }
        Some(CallSample {
            tester: self.id,
            seq: inv.seq,
            t_submit_local: inv.launched_local,
            t_done_local: now_local,
            rt_s: rt,
            outcome,
        })
    }

    /// The tester-enforced timeout fired for token `token`.  Returns the
    /// timeout sample, or `None` if the invocation already completed.
    pub fn record_timeout(
        &mut self,
        now_local: f64,
        token: u64,
    ) -> Option<CallSample> {
        let inv = self.outstanding?;
        if inv.timeout_token != token {
            return None;
        }
        self.outstanding = None;
        self.consecutive_failures += 1;
        Some(CallSample {
            tester: self.id,
            seq: inv.seq,
            t_submit_local: inv.launched_local,
            t_done_local: now_local,
            rt_s: now_local - inv.launched_local,
            outcome: SampleOutcome::Timeout,
        })
    }

    /// A sync exchange completed; update the local clock map.
    pub fn record_sync(&mut self, p: SyncPoint) {
        self.clock.record(p);
    }

    /// Eviction-policy check (the §3 "delete the client" behaviour, with
    /// hysteresis: `k` consecutive failures).
    pub fn should_give_up(&self, k: u32) -> bool {
        k > 0 && self.consecutive_failures >= k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tester() -> Tester {
        let mut t = Tester::new(TesterId(0), NodeId(3));
        t.start(100.0, TestDescription {
            duration_s: 60.0,
            client_interval_s: 1.0,
            ..Default::default()
        });
        t
    }

    #[test]
    fn launch_pacing_is_interval_or_back_to_back() {
        let mut t = tester();
        assert!(t.can_launch(100.0));
        t.launch(100.0, RequestId(0));
        // quick completion at 100.3: next launch waits for the interval
        t.record_result(100.3, RequestId(0), SampleOutcome::Success, 0.0);
        assert_eq!(t.next_launch_local(100.3), 101.0);
        // slow client: launch at 101, completes at 105 -> back-to-back
        t.launch(101.0, RequestId(1));
        t.record_result(105.0, RequestId(1), SampleOutcome::Success, 0.0);
        assert_eq!(t.next_launch_local(105.0), 105.0);
    }

    #[test]
    fn rt_adjustment_subtracts_latency_estimate() {
        let mut t = tester();
        t.latency_estimate_s = 0.05; // one-way
        t.launch(100.0, RequestId(0));
        let s = t
            .record_result(101.0, RequestId(0), SampleOutcome::Success, 0.01)
            .unwrap();
        // span 1.0 - rtt 0.1 - exec 0.01
        assert!((s.rt_s - 0.89).abs() < 1e-12);
    }

    #[test]
    fn stale_response_after_timeout_is_dropped() {
        let mut t = tester();
        let inv = t.launch(100.0, RequestId(7));
        let to = t.record_timeout(100.0 + 300.0, inv.timeout_token);
        assert!(to.is_some());
        assert_eq!(to.unwrap().outcome, SampleOutcome::Timeout);
        // the response eventually shows up: ignored
        assert!(t
            .record_result(420.0, RequestId(7), SampleOutcome::Success, 0.0)
            .is_none());
    }

    #[test]
    fn stale_timeout_after_response_is_dropped() {
        let mut t = tester();
        let inv = t.launch(100.0, RequestId(7));
        t.record_result(101.0, RequestId(7), SampleOutcome::Success, 0.0)
            .unwrap();
        assert!(t.record_timeout(400.0, inv.timeout_token).is_none());
    }

    #[test]
    fn consecutive_failures_track_and_reset() {
        let mut t = tester();
        for i in 0..3u32 {
            t.launch(100.0 + i as f64, RequestId(i));
            t.record_result(
                100.5 + i as f64,
                RequestId(i),
                SampleOutcome::ServiceError,
                0.0,
            );
        }
        assert_eq!(t.consecutive_failures, 3);
        assert!(t.should_give_up(3));
        assert!(!t.should_give_up(4));
        t.launch(110.0, RequestId(9));
        t.record_result(110.5, RequestId(9), SampleOutcome::Success, 0.0);
        assert_eq!(t.consecutive_failures, 0);
    }

    #[test]
    fn duration_gate() {
        let t = tester();
        assert!(!t.duration_elapsed(159.9));
        assert!(t.duration_elapsed(160.0));
        assert!(!t.can_launch(160.0));
    }

    #[test]
    fn start_failure_sample() {
        let mut t = tester();
        let s = t.record_start_failure(105.0);
        assert_eq!(s.outcome, SampleOutcome::StartFailure);
        assert_eq!(s.seq, 0);
        assert_eq!(t.consecutive_failures, 1);
        // seq advanced; next launch respects pacing
        assert_eq!(t.next_launch_local(105.0), 106.0);
    }

    #[test]
    fn crash_and_revive_restores_running() {
        let mut t = tester();
        let gen0 = t.sync_gen;
        t.launch(100.0, RequestId(0));
        for _ in 0..2 {
            t.consecutive_failures += 1;
        }
        t.kill();
        assert_eq!(t.phase, Phase::Dead);
        assert!(t.outstanding.is_none());
        assert_eq!(t.crashes, 1);
        let restored = t.revive();
        assert_eq!(restored, Phase::Running);
        assert_eq!(t.phase, Phase::Running);
        assert_eq!(t.consecutive_failures, 0);
        assert!(t.sync_gen > gen0, "revive must invalidate the old sync chain");
        // reviving a live tester is a no-op
        let gen1 = t.sync_gen;
        assert_eq!(t.revive(), Phase::Running);
        assert_eq!(t.sync_gen, gen1);
    }

    #[test]
    fn revive_of_idle_tester_stays_idle() {
        let mut t = Tester::new(TesterId(1), NodeId(4));
        t.kill();
        assert_eq!(t.revive(), Phase::Idle);
        assert_eq!(t.phase, Phase::Idle);
    }

    #[test]
    fn double_kill_counts_once_and_preserves_pre_crash_phase() {
        let mut t = tester();
        t.kill();
        t.kill();
        assert_eq!(t.crashes, 1);
        assert_eq!(t.revive(), Phase::Running);
    }

    #[test]
    fn session_loss_stops_client_issue_immediately() {
        let mut t = tester();
        t.launch(100.0, RequestId(0));
        t.session_lost();
        assert_eq!(t.phase, Phase::Stopped);
        assert!(t.outstanding.is_none());
        // well inside the configured duration, yet no further launches
        assert!(!t.can_launch(100.5));
    }

    #[test]
    fn stop_clears_outstanding() {
        let mut t = tester();
        t.launch(100.0, RequestId(0));
        t.stop();
        assert_eq!(t.phase, Phase::Stopped);
        assert!(t.outstanding.is_none());
        assert!(!t.can_launch(101.0));
    }
}
