//! Ablation — what if DiPerF trusted the platform clocks (§3.1.2's
//! rejected design)?  Re-runs the analysis with RAW tester-local
//! timestamps instead of reconciled ones and quantifies the damage:
//! PlanetLab-grade skews smear samples across the time axis, destroying
//! the per-quantum series that every figure depends on.

use diperf::analysis::{self, AnalysisInput};
use diperf::experiment::{presets, run_experiment};
use diperf::experiments::{NUM_CLIENTS, NUM_QUANTA, WINDOW_S};

fn main() -> anyhow::Result<()> {
    println!("# Ablation — reconciled vs raw clocks\n");
    // WAN run with the default clock population (some skews in the
    // thousands of seconds, as the paper observed)
    let cfg = presets::prews_small(20, 900.0, 31);
    let r = run_experiment(&cfg);

    // reconciled (normal) path
    let inp = AnalysisInput::from_run(&r.data, NUM_QUANTA, WINDOW_S);
    let rec = analysis::analyze(&inp, NUM_QUANTA, NUM_CLIENTS);

    // ablated path: timestamps shifted by each tester's *true* clock
    // error at sample time (what raw local clocks would have reported,
    // reconstructed from simulation truth)
    let mut raw = inp.clone();
    let mut shifted = 0u64;
    for (i, s) in r.data.samples.iter().enumerate() {
        if s.t_end_true.is_finite() {
            // reconciliation error is (t_end - t_end_true); raw clocks
            // would instead be off by the node's full skew — recover it
            // from the tester's clock map being bypassed entirely:
            let node = r.data.testers[s.tester.index()].node;
            let _ = node;
            // approximate raw reading: true time + per-tester skew drawn
            // from the same population the testbed used (deterministic
            // per tester via its record)
            let skew = raw_skew_for(s.tester.0, cfg.seed);
            raw.t_end[i] = (s.t_end_true + skew) as f32;
            raw.t_start[i] = (s.t_end_true + skew - s.rt) as f32;
            shifted += 1;
        }
    }
    let abl = analysis::analyze(&raw, NUM_QUANTA, NUM_CLIENTS);

    // damage metrics
    let peak_rec = rec.load.iter().cloned().fold(0.0, f64::max);
    let peak_abl = abl.load.iter().cloned().fold(0.0, f64::max);
    let inrange_rec: f64 = rec.tput.iter().sum();
    let inrange_abl: f64 = abl.tput.iter().sum();
    // series distortion: how far the raw-clock load/throughput series
    // deviates from the reconciled one, relative to its mass — skews of
    // seconds displace samples by whole quanta even when they stay
    // inside the window
    let l1 = |a: &[f64], b: &[f64]| -> f64 {
        let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum();
        num / a.iter().sum::<f64>().max(1e-9)
    };
    let load_dist = l1(&rec.load, &abl.load);
    let tput_dist = l1(&rec.tput, &abl.tput);
    println!("samples shifted by raw-clock skews: {shifted}");
    println!(
        "completions landing inside the experiment window: \
         reconciled {inrange_rec:.0} vs raw {inrange_abl:.0}"
    );
    println!(
        "peak observed load: reconciled {peak_rec:.1} vs raw {peak_abl:.1}"
    );
    println!(
        "series distortion (relative L1): load {:.0}% / throughput {:.0}%",
        load_dist * 100.0,
        tput_dist * 100.0
    );
    println!(
        "reconciled mean rt {:.2} s vs raw-binned mean rt {:.2} s",
        rec.totals[2], abl.totals[2]
    );

    anyhow::ensure!(
        inrange_abl < inrange_rec,
        "wild skews should push some samples out of the window"
    );
    anyhow::ensure!(
        load_dist > 0.05 && tput_dist > 0.10,
        "raw clocks must visibly distort the series \
         (load {load_dist:.2}, tput {tput_dist:.2})"
    );
    println!(
        "\nablation confirms §3.1.2: raw platform clocks lose samples \
         and distort every per-quantum series; the time-stamp server is \
         load-bearing"
    );
    Ok(())
}

/// Deterministic per-tester skew from the paper's observed population
/// (most fine, some in the thousands of seconds).
fn raw_skew_for(tester: u32, seed: u64) -> f64 {
    use diperf::util::Pcg64;
    let mut rng = Pcg64::new(seed ^ 0xab1a71, tester as u64 + 1);
    let u = rng.next_f64();
    if u < 0.55 {
        rng.uniform(-0.1, 0.1)
    } else if u < 0.85 {
        rng.uniform(-30.0, 30.0)
    } else {
        let mag = diperf::util::dist::lognormal_median(&mut rng, 800.0, 2.5);
        if rng.chance(0.5) {
            mag
        } else {
            -mag
        }
    }
}
