//! E2 — regenerates Figure 4 (pre-WS GRAM per-machine service
//! utilization and fairness over the peak window).  The paper's claim:
//! "the service gives a relatively equal share of resources to the
//! clients" — fairness is flat across machine ids.

use diperf::experiment::presets;
use diperf::experiments::{fairness_cv, run_with_analysis};
use diperf::report::{per_client_csv, RunDir};
use diperf::util::Summary;

fn main() -> anyhow::Result<()> {
    println!("# E2 / Figure 4 — pre-WS GRAM utilization & fairness per machine\n");
    let run = run_with_analysis(&presets::prews_fig3(42));

    let active: Vec<usize> = (0..run.out.completed.len())
        .filter(|&i| run.out.completed[i] > 0.0)
        .collect();
    let utils: Vec<f64> = active.iter().map(|&i| run.out.util[i]).collect();
    let fair: Vec<f64> = active.iter().map(|&i| run.out.fairness[i]).collect();
    let us = Summary::of(&utils);
    let fs = Summary::of(&fair);
    println!("machines with completions in peak window: {}", active.len());
    println!(
        "utilization: mean {:.4}  min {:.4}  max {:.4}  (ideal 1/{} = {:.4})",
        us.mean,
        us.min,
        us.max,
        active.len(),
        1.0 / active.len() as f64
    );
    println!(
        "fairness:    mean {:.1}  σ {:.1}  CV {:.3} (paper: 'relatively equal share')",
        fs.mean,
        fs.std,
        fairness_cv(&run)
    );

    let dir = RunDir::create("bench_out", "fig4")?;
    dir.write("fig4_per_client.csv", &per_client_csv(&run.out, &run.result.data))?;
    println!("\nseries -> bench_out/fig4/fig4_per_client.csv");

    // shape checks: ~89 active machines, near-uniform utilization
    anyhow::ensure!(active.len() >= 80, "most machines should be active");
    anyhow::ensure!(
        fairness_cv(&run) < 0.35,
        "pre-WS fairness must be flat (CV {})",
        fairness_cv(&run)
    );
    anyhow::ensure!(
        us.max / us.min.max(1e-9) < 3.0,
        "utilization spread too wide"
    );
    println!("figure 4 shape OK");
    Ok(())
}
