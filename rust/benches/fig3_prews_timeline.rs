//! E1 — regenerates Figure 3 (pre-WS GRAM response time, throughput and
//! load vs time) and checks the §4.1 headline shape.

use diperf::experiment::presets;
use diperf::experiments::{e1_headlines, md_header, run_with_analysis};
use diperf::report::{timeline_csv, RunDir};

fn main() -> anyhow::Result<()> {
    println!("# E1 / Figure 3 — GT3.2 pre-WS GRAM timeline\n");
    let t = std::time::Instant::now();
    let run = run_with_analysis(&presets::prews_fig3(42));
    println!(
        "experiment+analysis in {:.0} ms ({} events, analysis={})\n",
        t.elapsed().as_secs_f64() * 1e3,
        run.result.events,
        run.path
    );
    println!("{}", md_header());
    let mut ok = true;
    for h in e1_headlines(&run) {
        ok &= h.ok();
        println!("{}", h.md_row());
    }
    let dir = RunDir::create("bench_out", "fig3")?;
    dir.write(
        "fig3_timeline.csv",
        &timeline_csv(&run.out, run.inp.t0 as f64, run.inp.quantum as f64),
    )?;
    println!("\nseries -> bench_out/fig3/fig3_timeline.csv");
    anyhow::ensure!(ok, "figure 3 shape check failed");
    Ok(())
}
