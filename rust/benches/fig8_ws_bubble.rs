//! E6 — regenerates Figure 8 (WS GRAM: average aggregate load and jobs
//! completed per machine).  The paper: "only a few clients are not
//! given equal share, which is evident from the few bubbles that have a
//! significantly smaller surface area" — the shed victims.

use diperf::experiment::presets;
use diperf::experiments::run_with_analysis;
use diperf::report::{per_client_csv, RunDir};

fn main() -> anyhow::Result<()> {
    println!("# E6 / Figure 8 — WS GRAM load vs completions per machine\n");
    let run = run_with_analysis(&presets::ws_fig6(42));
    let d = &run.result.data;

    let n = d.testers.len();
    let mut done = vec![0u64; n];
    for s in &d.samples {
        if s.outcome.ok() {
            done[s.tester.index()] += 1;
        }
    }
    let survivors: Vec<u64> = (0..n)
        .filter(|&i| !d.testers[i].evicted)
        .map(|i| done[i])
        .collect();
    let victims: Vec<u64> = (0..n)
        .filter(|&i| d.testers[i].evicted)
        .map(|i| done[i])
        .collect();
    let mean = |v: &[u64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<u64>() as f64 / v.len() as f64
        }
    };
    println!(
        "survivor machines: {} (mean {:.1} jobs each)",
        survivors.len(),
        mean(&survivors)
    );
    println!(
        "shed/evicted machines: {} (mean {:.1} jobs each) — the small \
         bubbles",
        victims.len(),
        mean(&victims)
    );

    let dir = RunDir::create("bench_out", "fig8")?;
    dir.write("fig8_bubble.csv", &per_client_csv(&run.out, d))?;
    println!("\nseries -> bench_out/fig8/fig8_bubble.csv");

    anyhow::ensure!(
        !victims.is_empty() && victims.len() < n / 2,
        "'a few' machines should be shed, got {}/{n}",
        victims.len()
    );
    anyhow::ensure!(
        mean(&victims) < mean(&survivors) * 0.6,
        "victims' bubbles must be markedly smaller"
    );
    println!("figure 8 shape OK");
    Ok(())
}
