//! L3 microbenchmarks: the DES engine and the PS queue — the two hot
//! paths under every experiment.  Targets (DESIGN.md §7): >= 1 M
//! events/s through the engine.

use diperf::bench_util::{md_header, Bench};
use diperf::ids::RequestId;
use diperf::services::ps::PsQueue;
use diperf::sim::{Engine, SimTime};
use diperf::util::Pcg64;

fn main() -> anyhow::Result<()> {
    println!("# L3 hot paths\n\n{}", md_header());

    // raw engine: schedule + drain N events with random times
    let n = 1_000_000u64;
    let b = Bench::new("engine schedule+drain 1M events")
        .warmup(1)
        .iters(5)
        .run_with_units(n as f64, || {
            let mut eng: Engine<u64> = Engine::new();
            let mut rng = Pcg64::seed_from(1);
            for i in 0..n {
                eng.schedule(SimTime(rng.next_below(1 << 30)), i);
            }
            let mut acc = 0u64;
            while let Some((_, e)) = eng.next() {
                acc = acc.wrapping_add(e);
            }
            acc
        });
    println!("{}", b.md_row());
    let engine_rate = b.rate().unwrap_or(0.0);

    // cascading pattern (each event schedules a successor — the tester
    // launch loop's shape)
    let b2 = Bench::new("engine event cascade 1M")
        .warmup(1)
        .iters(5)
        .run_with_units(1e6, || {
            let mut eng: Engine<u32> = Engine::new();
            eng.schedule(SimTime(0), 0);
            let mut count = 0u64;
            eng.run_until(SimTime::MAX, |eng, t, e| {
                count += 1;
                if count < 1_000_000 {
                    eng.schedule(SimTime(t.0 + 3), e);
                }
            });
            count
        });
    println!("{}", b2.md_row());

    // PS queue churn at GRAM-like concurrency (90 jobs resident)
    let b3 = Bench::new("ps queue 100k ops at n=90")
        .warmup(1)
        .iters(5)
        .run_with_units(1e5, || {
            let mut q = PsQueue::new(1.0);
            let mut now = 0.0f64;
            for i in 0..90u32 {
                q.push(SimTime::from_secs_f64(now), RequestId(i), 1.0);
            }
            let mut next = 90u32;
            for _ in 0..100_000 {
                now += 0.01;
                for (done, _) in q.advance(SimTime::from_secs_f64(now)) {
                    let _ = done;
                    q.push(SimTime::from_secs_f64(now), RequestId(next), 1.0);
                    next += 1;
                }
            }
            q.len()
        });
    println!("{}", b3.md_row());

    println!(
        "\nengine rate {:.2} M events/s (target >= 1 M/s)",
        engine_rate / 1e6
    );
    anyhow::ensure!(engine_rate >= 1e6, "engine below the 1M events/s target");
    Ok(())
}
