//! E13 — the analysis-offload bench: the AOT-compiled XLA pipeline vs
//! the native rust pipeline on identical inputs.  Checks numerical
//! equivalence and compares wall time per analysis call (the online
//! view re-analyzes every few minutes, so this must be far below the
//! 5-minute budget).

use diperf::analysis::{self, AnalysisInput};
use diperf::bench_util::{md_header, Bench};
use diperf::experiment::presets;
use diperf::experiment::run_experiment;
use diperf::experiments::{NUM_CLIENTS, NUM_QUANTA, WINDOW_S};
use diperf::runtime::XlaAnalyzer;

fn main() -> anyhow::Result<()> {
    println!("# E13 — XLA vs native automated analysis\n");
    let r = run_experiment(&presets::prews_fig3(42));
    let inp = AnalysisInput::from_run(&r.data, NUM_QUANTA, WINDOW_S);
    println!(
        "input: {} samples -> padded variant selection from artifacts/\n",
        inp.len()
    );

    let mut xla = XlaAnalyzer::load("artifacts")?;
    let x_out = xla.analyze(&inp)?;
    let n_out = analysis::analyze(&inp, NUM_QUANTA, NUM_CLIENTS);

    // equivalence
    let d = |a: &[f64], b: &[f64]| -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
    };
    let d_tput = d(&x_out.tput, &n_out.tput);
    let d_load = d(&x_out.load, &n_out.load);
    let d_rt = d(&x_out.rt_ma, &n_out.rt_ma);
    let d_util = d(&x_out.util, &n_out.util);
    println!(
        "max deltas: tput {d_tput:.2e}  load {d_load:.2e}  rt_ma \
         {d_rt:.2e}  util {d_util:.2e}\n"
    );
    anyhow::ensure!(d_tput < 1e-3 && d_load < 0.05 && d_rt < 0.05,
        "XLA and native analyses diverged");

    // timing
    println!("{}", md_header());
    let bx = Bench::new("xla analyze (compiled, cached)")
        .warmup(2)
        .iters(10)
        .run_with_units(inp.len() as f64, || xla.analyze(&inp).unwrap());
    println!("{}", bx.md_row());
    let bn = Bench::new("native analyze")
        .warmup(2)
        .iters(10)
        .run_with_units(inp.len() as f64, || {
            analysis::analyze(&inp, NUM_QUANTA, NUM_CLIENTS)
        });
    println!("{}", bn.md_row());
    println!(
        "\nxla/native wall ratio: {:.2}x; online-view budget (300 s) \
         used: {:.4}%",
        bx.times.median / bn.times.median,
        100.0 * bx.times.median / 300.0
    );
    anyhow::ensure!(
        bx.times.median < 30.0,
        "analysis must fit far inside the online-view period"
    );
    println!("E13 OK");
    Ok(())
}
