//! E4 — regenerates Figure 6 (WS GRAM response time, throughput and
//! load vs time) including the §4.2 overload signature: throughput
//! collapse past ~20 clients, client failures shedding load back to
//! capacity, and recovery to ~10 jobs/min.

use diperf::experiment::presets;
use diperf::experiments::{e4_headlines, md_header, run_with_analysis};
use diperf::report::{timeline_csv, RunDir};

fn main() -> anyhow::Result<()> {
    println!("# E4 / Figure 6 — GT3.2 WS GRAM timeline\n");
    let run = run_with_analysis(&presets::ws_fig6(42));
    println!("{}", md_header());
    let mut ok = true;
    for h in e4_headlines(&run) {
        ok &= h.ok();
        println!("{}", h.md_row());
    }
    let evicted = run.result.data.testers.iter().filter(|t| t.evicted).count();
    println!(
        "\ntesters evicted after service shedding: {evicted} \
         (paper: 26 -> ~20 machines)"
    );

    // the aborted 89-client attempt (same figure's narrative)
    let over = run_with_analysis(&presets::ws_overload(42));
    println!(
        "89-client attempt: {} ok / {} failed, {} hard stalls (paper: \
         'service stalled and all clients failed')",
        over.result.data.completed(),
        over.result.data.failed(),
        over.result.stalls
    );

    let dir = RunDir::create("bench_out", "fig6")?;
    dir.write(
        "fig6_timeline.csv",
        &timeline_csv(&run.out, run.inp.t0 as f64, run.inp.quantum as f64),
    )?;
    println!("\nseries -> bench_out/fig6/fig6_timeline.csv");

    anyhow::ensure!(ok, "figure 6 shape check failed");
    anyhow::ensure!(evicted >= 2, "shedding must evict testers");
    anyhow::ensure!(over.result.stalls >= 1, "overload must hard-stall");
    anyhow::ensure!(
        over.result.data.failed() * 2 > over.result.data.completed(),
        "overload failures must dominate"
    );
    println!("figure 6 shape OK");
    Ok(())
}
