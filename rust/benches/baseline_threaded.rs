//! E10 — the §2 baseline contrast: the Globus-test-suite style
//! single-node threaded harness vs DiPerF's distributed testers, on the
//! same target service.  The paper's critique, quantified: the threaded
//! harness (1) saturates its own client machine before the service when
//! clients are resource-intensive, and (2) sees zero latency diversity.

use diperf::baseline::{run_threaded, ThreadedHarnessConfig};
use diperf::experiment::presets;
use diperf::experiments::run_with_analysis;
use diperf::services::gram_prews::{GramPrews, GramPrewsParams};

fn main() -> anyhow::Result<()> {
    println!("# E10 / §2 — single-node threaded harness vs DiPerF\n");

    // resource-intensive client (the GRAM client is a heavyweight
    // executable): 180 ms of client CPU per invocation
    let mk = || GramPrews::new(GramPrewsParams::default());

    println!("threads | svc load | client cpu busy | tput/min");
    let mut svc_loads = Vec::new();
    for threads in [8, 16, 32, 64, 128] {
        let mut svc = mk();
        let r = run_threaded(
            &ThreadedHarnessConfig {
                threads,
                client_demand_s: 0.18,
                duration_s: 900.0,
                mem_slots: 24, // heavyweight GRAM clients: ~24 fit in RAM
                ..Default::default()
            },
            &mut svc,
        );
        println!(
            "{threads:>7} | {:>8.1} | {:>15.2} | {:>8.1}",
            r.mean_service_load, r.client_cpu_busy_frac, r.tput_per_min
        );
        svc_loads.push(r.mean_service_load);
    }
    let max_threaded_load = svc_loads.iter().cloned().fold(0.0, f64::max);

    // DiPerF reaches deep saturation with the same service
    let run = run_with_analysis(&presets::prews_fig3(42));
    let diperf_peak_load = run.out.totals[3];
    println!(
        "\nthreaded harness peak service load: {max_threaded_load:.1} \
         concurrent requests"
    );
    println!(
        "DiPerF (89 WAN testers) peak load:  {diperf_peak_load:.1} \
         concurrent requests"
    );
    println!(
        "-> DiPerF saturates {:.1}x deeper (paper: threaded harnesses \
         make services 'relatively hard to saturate')",
        diperf_peak_load / max_threaded_load.max(1e-9)
    );

    // latency diversity: DiPerF's testers span a WAN
    let lat_spread = {
        let rts: Vec<f64> = run.result.sync.rtts_s.clone();
        let s = diperf::util::Summary::of(&rts);
        s.p99 / s.median.max(1e-9)
    };
    println!(
        "DiPerF latency diversity (p99/median rtt): {lat_spread:.1}x; \
         threaded harness: 1.0x by construction"
    );

    anyhow::ensure!(
        diperf_peak_load > 2.0 * max_threaded_load,
        "DiPerF must saturate substantially deeper than the threaded \
         harness"
    );
    anyhow::ensure!(lat_spread > 2.0, "WAN latency diversity missing");
    println!("\n§2 baseline contrast OK");
    Ok(())
}
