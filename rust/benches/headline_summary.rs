//! E9 — the §5 summary table: every headline number the paper quotes
//! for both GRAM services, side by side with our reproduction.

use diperf::experiment::presets;
use diperf::experiments::{
    e1_headlines, e4_headlines, md_header, run_with_analysis,
};

fn main() -> anyhow::Result<()> {
    println!("# E9 / §5 — headline summary, paper vs reproduction\n");
    let prews = run_with_analysis(&presets::prews_fig3(42));
    let ws = run_with_analysis(&presets::ws_fig6(42));

    println!("## pre-WS GRAM\n\n{}", md_header());
    let mut ok = true;
    for h in e1_headlines(&prews) {
        ok &= h.ok();
        println!("{}", h.md_row());
    }
    println!("\n## WS GRAM\n\n{}", md_header());
    for h in e4_headlines(&ws) {
        ok &= h.ok();
        println!("{}", h.md_row());
    }

    // the paper's comparative claims
    let ratio = diperf::experiments::peak_tput_per_min(&prews)
        / diperf::experiments::peak_tput_per_min(&ws).max(1e-9);
    println!(
        "\npre-WS vs WS throughput ratio: {ratio:.1}x (paper: ~20x — \
         200 vs 10 jobs/min)"
    );
    let cv_ratio = diperf::experiments::fairness_cv(&ws)
        / diperf::experiments::fairness_cv(&prews).max(1e-9);
    println!(
        "WS/pre-WS fairness-variability ratio: {cv_ratio:.1}x (paper: \
         pre-WS 'allocates resources more evenly')"
    );

    anyhow::ensure!(ok, "headline table failed");
    anyhow::ensure!(ratio > 5.0, "pre-WS must dominate WS throughput");
    anyhow::ensure!(cv_ratio > 1.0, "WS must be less fair than pre-WS");
    println!("\n§5 summary shape OK");
    Ok(())
}
