//! The tracked scale benchmark: 1k/10k/100k-tester churn runs under
//! both event queues, plus a retain-vs-stream memory probe and a
//! queue-only microbenchmark.  Emits `BENCH_scale.json` (wall time,
//! events/sec, peak RSS, peak queue length) so every future PR has a
//! perf trajectory to regress against — the MongoDB lesson (Ingo &
//! Daly 2020): performance work without a tracked artifact melts away.
//!
//! Controls:
//! - `DIPERF_BENCH_SIZES=1000,10000` — tester pools (CI smoke uses
//!   `1000`); default sweeps 1k/10k/100k.
//! - `DIPERF_BENCH_DURATION=60` — virtual seconds per run (default
//!   300; the million-tester CI row shortens it to stay affordable).
//! - `DIPERF_BENCH_SHARDS=1,4` — switch to *sharded-world* mode: one
//!   `churn-{n}-shard{S}-stream` row per pool size and shard count,
//!   **appended** to an existing `BENCH_scale.json` (the single-engine
//!   sweep, retain probe and queue microbenchmark are skipped), plus a
//!   `testers_per_core` summary field (largest pool / its largest
//!   shard count).  See `docs/BENCH_scale.md`.
//! - `DIPERF_BENCH_OVERHEAD=1` — *flight-recorder overhead* mode: the
//!   largest pool size runs twice, recorder off then on, and the
//!   `harness_overhead` summary field records the wall-time ratio
//!   (`churn-{n}-obsv_off` / `churn-{n}-obsv_on` rows are appended).
//!   At >= 100k testers the ratio is gated at 1.05 — the recorder's
//!   contract is near-zero cost (see `docs/OBSERVABILITY.md`).
//!
//! Memory metric: every row's `peak_rss_kb` is the phase's own peak
//! resident set, measured by [`RssProbe`] (a sampler over `VmRSS` with
//! a `/proc/self/statm` fallback).  The process-lifetime `VmHWM`
//! watermark is *not* used per row: resetting it requires a writable
//! `/proc/self/clear_refs`, which CI containers deny, and without the
//! reset every phase after the biggest one inherits its peak.

use diperf::bench_util::{
    md_header, scale_json, upsert_scale_field, Bench, RssProbe, ScaleRow,
};
use diperf::experiment::{presets, run_experiment_opts, RunOptions};
use diperf::metrics::CollectionMode;
use diperf::sim::{Engine, QueueKind, SimTime};
use diperf::util::Pcg64;

fn env_list(name: &str) -> Vec<usize> {
    std::env::var(name)
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect()
        })
        .unwrap_or_default()
}

fn sizes() -> Vec<usize> {
    let parsed = env_list("DIPERF_BENCH_SIZES");
    if parsed.is_empty() {
        vec![1_000, 10_000, 100_000]
    } else {
        parsed
    }
}

fn duration_s() -> f64 {
    std::env::var("DIPERF_BENCH_DURATION")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .filter(|d: &f64| *d > 0.0)
        .unwrap_or(300.0)
}

/// One measured experiment run (single iteration: the big runs are tens
/// of seconds of wall time and perfectly deterministic).
fn run_once(
    n: usize,
    duration: f64,
    queue: QueueKind,
    collect: CollectionMode,
    shards: Option<usize>,
) -> ScaleRow {
    let cfg = presets::bench_scale(n, duration, 42);
    let probe = RssProbe::start();
    let t = std::time::Instant::now();
    let r = run_experiment_opts(
        &cfg,
        RunOptions {
            queue,
            collect,
            shards,
            ..RunOptions::default()
        },
    );
    let wall_s = t.elapsed().as_secs_f64().max(1e-9);
    let peak_rss_kb = probe.stop();
    let samples = match r.stream.as_ref() {
        Some(agg) => agg.samples_seen,
        None => r.data.samples.len() as u64,
    };
    let label = match shards {
        Some(s) => format!("churn-{n}-shard{s}-{}", collect.label()),
        None => format!("churn-{n}-{}-{}", queue.label(), collect.label()),
    };
    ScaleRow {
        label,
        testers: n,
        queue: queue.label(),
        collection: collect.label(),
        virtual_s: r.data.duration_s,
        wall_s,
        events: r.events,
        events_per_sec: r.events as f64 / wall_s,
        peak_pending: r.peak_pending,
        peak_rss_kb,
        samples,
    }
}

/// Queue-only microbenchmark at scale-typical pending populations:
/// schedule/drain with ~2 events per tester resident.  Returns
/// events/sec for the given queue.
fn queue_rate(kind: QueueKind, resident: usize) -> f64 {
    let total: u64 = 2_000_000;
    let b = Bench::new(format!("queue {} resident {resident}", kind.label()))
        .warmup(1)
        .iters(3)
        .run_with_units(total as f64, || {
            let mut eng: Engine<u64> = Engine::with_queue(kind);
            let mut rng = Pcg64::seed_from(7);
            // fill to the resident population, then steady-state
            // pop-one-push-one like a running experiment
            for i in 0..resident as u64 {
                eng.schedule(SimTime(rng.next_below(1 << 27)), i);
            }
            let mut acc = 0u64;
            for i in 0..total {
                let (t, e) = eng.next().expect("resident events");
                acc = acc.wrapping_add(e);
                eng.schedule(
                    SimTime(t.0 + 1 + rng.next_below(1 << 24)),
                    i,
                );
            }
            acc
        });
    println!("{}", b.md_row());
    b.rate().unwrap_or(0.0)
}

/// Sharded-world mode: measure `sizes x shard counts`, append the rows
/// to the existing trajectory and record `testers_per_core`.
fn run_sharded(sizes: &[usize], shard_counts: &[usize], duration: f64) -> anyhow::Result<()> {
    let mut rows: Vec<ScaleRow> = Vec::new();
    for &n in sizes {
        for &s in shard_counts {
            let row = run_once(n, duration, QueueKind::Wheel, CollectionMode::Stream, Some(s));
            println!(
                "n={n} S={s}: {:.2}s wall, {:.2} M ev/s, {} samples, \
                 peak rss {} kB",
                row.wall_s,
                row.events_per_sec / 1e6,
                row.samples,
                row.peak_rss_kb,
            );
            anyhow::ensure!(row.samples > 0, "sharded run produced no samples");
            rows.push(row);
        }
    }
    let path = "BENCH_scale.json";
    diperf::bench_util::append_or_init(path, &rows)?;
    // headline scaling figure: how many simulated testers each core
    // carried in the largest sharded configuration
    let max_n = sizes.iter().copied().max().unwrap_or(1);
    let max_s = shard_counts.iter().copied().max().unwrap_or(1).max(1);
    let doc = std::fs::read_to_string(path)?;
    if let Some(doc) = upsert_scale_field(&doc, "testers_per_core", &format!("{}", max_n / max_s)) {
        std::fs::write(path, doc)?;
    }
    println!("\nappended {} sharded rows to {path}", rows.len());
    Ok(())
}

/// Flight-recorder overhead mode: the same churn run with the recorder
/// off, then on; `harness_overhead = wall_on / wall_off` is the
/// self-metric the perf gate tracks.  The recorder's own event counts
/// are printed (and must be nonzero with the recorder on — a silent
/// no-op instrumentation layer would make the ratio meaningless).
fn run_overhead(sizes: &[usize], duration: f64) -> anyhow::Result<()> {
    let n = sizes.iter().copied().max().unwrap_or(1_000);
    let mut off =
        run_once(n, duration, QueueKind::Wheel, CollectionMode::Stream, None);
    off.label = format!("churn-{n}-obsv_off");

    diperf::obsv::enable();
    let mut on =
        run_once(n, duration, QueueKind::Wheel, CollectionMode::Stream, None);
    on.label = format!("churn-{n}-obsv_on");
    let recorded = diperf::obsv::counter(diperf::obsv::Kind::SimEvents);
    println!("{}", diperf::obsv::stats_line());
    diperf::obsv::disable();
    diperf::obsv::reset();
    anyhow::ensure!(
        recorded > 0,
        "recorder-on run recorded no sim events — instrumentation dead?"
    );
    anyhow::ensure!(
        on.events == off.events && on.samples == off.samples,
        "recorder changed the run: {} vs {} events, {} vs {} samples",
        on.events,
        off.events,
        on.samples,
        off.samples
    );

    let overhead = on.wall_s / off.wall_s.max(1e-9);
    println!(
        "n={n}: recorder off {:.3}s vs on {:.3}s -> harness_overhead {overhead:.4}",
        off.wall_s, on.wall_s
    );
    let path = "BENCH_scale.json";
    diperf::bench_util::append_or_init(path, &[off, on])?;
    let doc = std::fs::read_to_string(path)?;
    if let Some(doc) =
        upsert_scale_field(&doc, "harness_overhead", &format!("{overhead:.4}"))
    {
        std::fs::write(path, doc)?;
    }
    println!("appended overhead rows to {path}");
    // Gate only at full scale: tiny smoke runs finish in milliseconds
    // and the ratio there is scheduler noise, not recorder cost.
    if n >= 100_000 {
        anyhow::ensure!(
            overhead <= 1.05,
            "flight recorder costs {:.1}% at n={n} (budget 5%)",
            (overhead - 1.0) * 100.0
        );
    } else {
        println!("(overhead gate skipped below 100k testers — smoke run)");
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let duration = duration_s();
    let sizes = sizes();
    if std::env::var("DIPERF_BENCH_OVERHEAD").is_ok_and(|v| v == "1") {
        println!(
            "# flight-recorder overhead benchmark (churn, {duration:.0} \
             virtual s)\n"
        );
        return run_overhead(&sizes, duration);
    }
    let shard_counts = env_list("DIPERF_BENCH_SHARDS");
    if !shard_counts.is_empty() {
        println!(
            "# sharded scale-out benchmark (churn, {duration:.0} virtual s)\n"
        );
        return run_sharded(&sizes, &shard_counts, duration);
    }
    println!("# scale-out benchmark (churn, {duration:.0} virtual s)\n");
    println!("{}", md_header());

    let mut rows: Vec<ScaleRow> = Vec::new();
    let max_n = sizes.iter().copied().max().unwrap_or(1_000);

    // retain-vs-stream memory probe at an affordable size (each phase
    // measures its own peak via the RSS sampler, but allocator reuse
    // still makes first-position the fairest slot for the retained run)
    let probe_n = max_n.min(10_000);
    let retain_row = run_once(
        probe_n,
        duration,
        QueueKind::Wheel,
        CollectionMode::Retain,
        None,
    );
    println!(
        "retain {probe_n}: {:.2}s, {} samples, peak rss {} kB",
        retain_row.wall_s, retain_row.samples, retain_row.peak_rss_kb
    );
    rows.push(retain_row);

    // the main sweep: streaming collection under both queues
    let mut wheel_vs_heap_at_max = 0.0;
    for &n in &sizes {
        let wheel =
            run_once(n, duration, QueueKind::Wheel, CollectionMode::Stream, None);
        let heap =
            run_once(n, duration, QueueKind::Heap, CollectionMode::Stream, None);
        let ratio = wheel.events_per_sec / heap.events_per_sec.max(1.0);
        println!(
            "n={n}: wheel {:.2} M ev/s vs heap {:.2} M ev/s ({ratio:.2}x), \
             peak pending {}, stream rss {} kB",
            wheel.events_per_sec / 1e6,
            heap.events_per_sec / 1e6,
            wheel.peak_pending,
            wheel.peak_rss_kb,
        );
        if n == max_n {
            wheel_vs_heap_at_max = ratio;
        }
        rows.push(wheel);
        rows.push(heap);
    }

    // queue-only rates at the max pool's resident population — the
    // isolated data-structure comparison behind the experiment ratio
    let resident = (2 * max_n).max(1_000);
    let qw = queue_rate(QueueKind::Wheel, resident);
    let qh = queue_rate(QueueKind::Heap, resident);
    let queue_ratio = qw / qh.max(1.0);
    println!(
        "\nqueue-only at {resident} resident: wheel {:.2} M/s vs heap \
         {:.2} M/s ({queue_ratio:.2}x)",
        qw / 1e6,
        qh / 1e6
    );

    let doc = scale_json(
        &rows,
        &[
            ("virtual_s", format!("{duration:.1}")),
            ("seed", "42".into()),
            ("wheel_vs_heap_experiment", format!("{wheel_vs_heap_at_max:.3}")),
            ("wheel_vs_heap_queue_only", format!("{queue_ratio:.3}")),
            ("queue_only_resident", format!("{resident}")),
            // CI-only fields: the plain sweep never measures these, so
            // it writes null placeholders for the CI upserts to fill
            // (docs/BENCH_scale.md).
            ("testers_per_core", "null".into()),
            ("harness_overhead", "null".into()),
        ],
    );
    std::fs::write("BENCH_scale.json", &doc)?;
    println!("\nwrote BENCH_scale.json ({} rows)", rows.len());

    // Regression guards — only at full scale.  The wheel's design
    // target is 10^5+ resident events; at the CI smoke's 1k-tester
    // population a cache-hot 11-level heap is genuinely competitive,
    // so asserting a ratio there would just make the smoke flaky.
    if max_n >= 100_000 {
        anyhow::ensure!(
            wheel_vs_heap_at_max >= 0.95,
            "wheel slower than heap at n={max_n}: {wheel_vs_heap_at_max:.2}x"
        );
        anyhow::ensure!(
            queue_ratio >= 1.2,
            "queue-only speedup collapsed: {queue_ratio:.2}x"
        );
    } else {
        println!(
            "(ratio guards skipped below 100k testers — smoke run)"
        );
    }
    Ok(())
}
