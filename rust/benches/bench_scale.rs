//! The tracked scale benchmark: 1k/10k/100k-tester churn runs under
//! both event queues, plus a retain-vs-stream memory probe and a
//! queue-only microbenchmark.  Emits `BENCH_scale.json` (wall time,
//! events/sec, peak RSS, peak queue length) so every future PR has a
//! perf trajectory to regress against — the MongoDB lesson (Ingo &
//! Daly 2020): performance work without a tracked artifact melts away.
//!
//! Size control: `DIPERF_BENCH_SIZES=1000,10000` (CI smoke uses
//! `1000`); default sweeps 1k/10k/100k.

use diperf::bench_util::{
    md_header, peak_rss_kb, reset_peak_rss, scale_json, Bench, ScaleRow,
};
use diperf::experiment::{presets, run_experiment_opts, RunOptions};
use diperf::metrics::CollectionMode;
use diperf::sim::{Engine, QueueKind, SimTime};
use diperf::util::Pcg64;

const DURATION_S: f64 = 300.0;

fn sizes() -> Vec<usize> {
    let parsed: Vec<usize> = std::env::var("DIPERF_BENCH_SIZES")
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.trim().parse().ok())
                .collect()
        })
        .unwrap_or_default();
    if parsed.is_empty() {
        vec![1_000, 10_000, 100_000]
    } else {
        parsed
    }
}

/// One measured experiment run (single iteration: the big runs are tens
/// of seconds of wall time and perfectly deterministic).
fn run_once(n: usize, queue: QueueKind, collect: CollectionMode) -> ScaleRow {
    let cfg = presets::bench_scale(n, DURATION_S, 42);
    let rss_reset = reset_peak_rss();
    let t = std::time::Instant::now();
    let r = run_experiment_opts(
        &cfg,
        RunOptions {
            queue,
            collect,
            ..RunOptions::default()
        },
    );
    let wall_s = t.elapsed().as_secs_f64().max(1e-9);
    let samples = match r.stream.as_ref() {
        Some(agg) => agg.samples_seen,
        None => r.data.samples.len() as u64,
    };
    ScaleRow {
        label: format!(
            "churn-{n}-{}-{}{}",
            queue.label(),
            collect.label(),
            if rss_reset { "" } else { "-norss" }
        ),
        testers: n,
        queue: queue.label(),
        collection: collect.label(),
        virtual_s: r.data.duration_s,
        wall_s,
        events: r.events,
        events_per_sec: r.events as f64 / wall_s,
        peak_pending: r.peak_pending,
        peak_rss_kb: peak_rss_kb(),
        samples,
    }
}

/// Queue-only microbenchmark at scale-typical pending populations:
/// schedule/drain with ~2 events per tester resident.  Returns
/// events/sec for the given queue.
fn queue_rate(kind: QueueKind, resident: usize) -> f64 {
    let total: u64 = 2_000_000;
    let b = Bench::new(format!("queue {} resident {resident}", kind.label()))
        .warmup(1)
        .iters(3)
        .run_with_units(total as f64, || {
            let mut eng: Engine<u64> = Engine::with_queue(kind);
            let mut rng = Pcg64::seed_from(7);
            // fill to the resident population, then steady-state
            // pop-one-push-one like a running experiment
            for i in 0..resident as u64 {
                eng.schedule(SimTime(rng.next_below(1 << 27)), i);
            }
            let mut acc = 0u64;
            for i in 0..total {
                let (t, e) = eng.next().expect("resident events");
                acc = acc.wrapping_add(e);
                eng.schedule(
                    SimTime(t.0 + 1 + rng.next_below(1 << 24)),
                    i,
                );
            }
            acc
        });
    println!("{}", b.md_row());
    b.rate().unwrap_or(0.0)
}

fn main() -> anyhow::Result<()> {
    println!("# scale-out benchmark (churn, {DURATION_S:.0} virtual s)\n");
    println!("{}", md_header());

    let mut rows: Vec<ScaleRow> = Vec::new();
    let sizes = sizes();
    let max_n = sizes.iter().copied().max().unwrap_or(1_000);

    // retain-vs-stream memory probe at an affordable size: do it first
    // so the retained run's RSS cannot be masked by later, larger runs
    // on kernels where the high-water mark is not resettable
    let probe_n = max_n.min(10_000);
    let retain_row = run_once(probe_n, QueueKind::Wheel, CollectionMode::Retain);
    println!(
        "retain {probe_n}: {:.2}s, {} samples, peak rss {} kB",
        retain_row.wall_s, retain_row.samples, retain_row.peak_rss_kb
    );
    rows.push(retain_row);

    // the main sweep: streaming collection under both queues
    let mut wheel_vs_heap_at_max = 0.0;
    for &n in &sizes {
        let wheel = run_once(n, QueueKind::Wheel, CollectionMode::Stream);
        let heap = run_once(n, QueueKind::Heap, CollectionMode::Stream);
        let ratio = wheel.events_per_sec / heap.events_per_sec.max(1.0);
        println!(
            "n={n}: wheel {:.2} M ev/s vs heap {:.2} M ev/s ({ratio:.2}x), \
             peak pending {}, stream rss {} kB",
            wheel.events_per_sec / 1e6,
            heap.events_per_sec / 1e6,
            wheel.peak_pending,
            wheel.peak_rss_kb,
        );
        if n == max_n {
            wheel_vs_heap_at_max = ratio;
        }
        rows.push(wheel);
        rows.push(heap);
    }

    // queue-only rates at the max pool's resident population — the
    // isolated data-structure comparison behind the experiment ratio
    let resident = (2 * max_n).max(1_000);
    let qw = queue_rate(QueueKind::Wheel, resident);
    let qh = queue_rate(QueueKind::Heap, resident);
    let queue_ratio = qw / qh.max(1.0);
    println!(
        "\nqueue-only at {resident} resident: wheel {:.2} M/s vs heap \
         {:.2} M/s ({queue_ratio:.2}x)",
        qw / 1e6,
        qh / 1e6
    );

    let doc = scale_json(
        &rows,
        &[
            ("virtual_s", format!("{DURATION_S:.1}")),
            ("seed", "42".into()),
            ("wheel_vs_heap_experiment", format!("{wheel_vs_heap_at_max:.3}")),
            ("wheel_vs_heap_queue_only", format!("{queue_ratio:.3}")),
            ("queue_only_resident", format!("{resident}")),
        ],
    );
    std::fs::write("BENCH_scale.json", &doc)?;
    println!("\nwrote BENCH_scale.json ({} rows)", rows.len());

    // Regression guards — only at full scale.  The wheel's design
    // target is 10^5+ resident events; at the CI smoke's 1k-tester
    // population a cache-hot 11-level heap is genuinely competitive,
    // so asserting a ratio there would just make the smoke flaky.
    if max_n >= 100_000 {
        anyhow::ensure!(
            wheel_vs_heap_at_max >= 0.95,
            "wheel slower than heap at n={max_n}: {wheel_vs_heap_at_max:.2}x"
        );
        anyhow::ensure!(
            queue_ratio >= 1.2,
            "queue-only speedup collapsed: {queue_ratio:.2}x"
        );
    } else {
        println!(
            "(ratio guards skipped below 100k testers — smoke run)"
        );
    }
    Ok(())
}
