//! E8 — the §3.1.2 clock-synchronization study: 100+ PlanetLab-like
//! nodes syncing against the central time-stamp server every 5 minutes
//! for ~2 hours.  Paper: skew mean 62 ms / median 57 ms / σ 52 ms; the
//! majority of nodes under 80 ms latency; error bounded by the (route-
//! asymmetric) network latency.

use diperf::experiment::presets;
use diperf::experiment::run_experiment;
use diperf::experiments::{e8_headlines, md_header};

fn main() -> anyhow::Result<()> {
    println!("# E8 / §3.1.2 — clock-sync accuracy over the WAN\n");
    // ~2 h of virtual time, 100 nodes, 5-minute syncs — the paper's setup
    let mut cfg = presets::http_sec43(42);
    cfg.testbed.num_testers = 100;
    cfg.controller.desc.duration_s = 7200.0;
    cfg.controller.desc.rate_cap_per_s = 0.2; // light probe load
    let r = run_experiment(&cfg);

    println!("{}", md_header());
    let mut ok = true;
    for h in e8_headlines(&r) {
        ok &= h.ok();
        println!("{}", h.md_row());
    }
    let es = r.sync.error_summary();
    let rs = r.rtt_summary_check();
    println!(
        "\n{} sync exchanges; worst error {:.0} ms; max observed rtt \
         {:.0} ms",
        es.n,
        es.max * 1e3,
        rs.max * 1e3
    );
    // the paper's bound: error <= network latency (rtt, conservatively)
    anyhow::ensure!(
        es.max <= rs.max,
        "sync error must be bounded by network latency"
    );
    anyhow::ensure!(ok, "sync accuracy outside the paper's regime");
    println!("§3.1.2 shape OK");
    Ok(())
}

/// Local extension trait to reach the rtt summary without exporting more
/// API surface than the library needs.
trait RttCheck {
    fn rtt_summary_check(&self) -> diperf::util::Summary;
}

impl RttCheck for diperf::experiment::ExperimentResult {
    fn rtt_summary_check(&self) -> diperf::util::Summary {
        self.sync.rtt_summary()
    }
}
