//! E5 — regenerates Figure 7 (WS GRAM per-machine utilization and
//! fairness).  The paper: "service fairness varies significantly more
//! than it did for pre-WS GRAM."

use diperf::experiment::presets;
use diperf::experiments::{fairness_cv, run_with_analysis};
use diperf::report::{per_client_csv, RunDir};

fn main() -> anyhow::Result<()> {
    println!("# E5 / Figure 7 — WS GRAM utilization & fairness per machine\n");
    let ws = run_with_analysis(&presets::ws_fig6(42));
    let prews = run_with_analysis(&presets::prews_fig3(42));

    let cv_ws = fairness_cv(&ws);
    let cv_prews = fairness_cv(&prews);
    println!("fairness CV, WS GRAM:     {cv_ws:.4}");
    println!("fairness CV, pre-WS GRAM: {cv_prews:.4}");
    println!(
        "ratio {:.1}x (paper: WS GRAM 'varies significantly more')",
        cv_ws / cv_prews.max(1e-9)
    );

    // dispersion of per-client completions (the visible signal in Fig 7)
    let spread = |run: &diperf::experiments::FigureRun| {
        let v: Vec<f64> = run
            .out
            .completed
            .iter()
            .cloned()
            .filter(|&c| c > 0.0)
            .collect();
        let s = diperf::util::Summary::of(&v);
        s.std / s.mean.max(1e-9)
    };
    println!(
        "completion-count CV: WS {:.3} vs pre-WS {:.3}",
        spread(&ws),
        spread(&prews)
    );

    let dir = RunDir::create("bench_out", "fig7")?;
    dir.write("fig7_per_client.csv", &per_client_csv(&ws.out, &ws.result.data))?;
    println!("\nseries -> bench_out/fig7/fig7_per_client.csv");

    anyhow::ensure!(
        spread(&ws) > spread(&prews),
        "WS GRAM per-client dispersion must exceed pre-WS GRAM"
    );
    println!("figure 7 shape OK");
    Ok(())
}
