//! E7 — the §4.3 HTTP/CGI experiment: 125 clients at ≤ 3 jobs/s
//! saturate a default Apache; DiPerF's results stay consistent at
//! millisecond granularity.

use diperf::experiment::presets;
use diperf::experiments::{
    peak_tput_per_min, rt_heavy_load, rt_light_load, run_with_analysis,
};
use diperf::report::{timeline_csv, RunDir};

fn main() -> anyhow::Result<()> {
    println!("# E7 / §4.3 — Apache+CGI saturation\n");
    let run = run_with_analysis(&presets::http_sec43(42));
    let peak = peak_tput_per_min(&run);
    let rt_l = rt_light_load(&run);
    let rt_h = rt_heavy_load(&run);
    println!("peak throughput      {peak:.0} jobs/min (capacity ~3000)");
    println!("offered at full ramp {:.0} jobs/min", 125.0 * 3.0 * 60.0);
    println!("rt light load        {:.1} ms", rt_l * 1e3);
    println!("rt saturated         {:.2} s", rt_h);
    println!(
        "failures (denials)   {} of {}",
        run.result.data.failed(),
        run.result.data.samples.len()
    );

    let dir = RunDir::create("bench_out", "http")?;
    dir.write(
        "http_timeline.csv",
        &timeline_csv(&run.out, run.inp.t0 as f64, run.inp.quantum as f64),
    )?;
    println!("\nseries -> bench_out/http/http_timeline.csv");

    anyhow::ensure!(
        (2000.0..4000.0).contains(&peak),
        "saturation throughput {peak} outside capacity band"
    );
    anyhow::ensure!(rt_l < 0.5 && rt_h > rt_l, "granularity check failed");
    println!("§4.3 shape OK — fine-granularity services hold");
    Ok(())
}
