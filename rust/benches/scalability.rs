//! E11 — the §5 scalability claim: DiPerF "could scale to 1000s of
//! nodes".  Sweeps the tester pool and reports framework-side costs.

use diperf::bench_util::{md_header, Bench};
use diperf::experiment::{presets, run_experiment};

fn main() -> anyhow::Result<()> {
    println!("# E11 / §5 — framework scalability\n");
    println!("{}", md_header());
    let mut rates = Vec::new();
    for &n in &[100usize, 500, 1000, 2000] {
        let cfg = presets::scalability(n, 42);
        // time the full experiment (single iteration — it is seconds of
        // virtual time and the variance is tiny)
        let mut events = 0u64;
        let r = Bench::new(format!("experiment n={n}"))
            .warmup(0)
            .iters(3)
            .run(|| {
                let res = run_experiment(&cfg);
                events = res.events;
                res.data.samples.len()
            });
        let rate = events as f64 / r.times.median;
        rates.push(rate);
        println!("{}", {
            let mut row = r.md_row();
            row.push_str(&format!(" ev/s {:.2e}", rate));
            row
        });
    }
    println!(
        "\nevent rate at 2000 testers: {:.2} M events/s \
         ({:.0}% of the 100-tester rate — sub-linear degradation only)",
        rates[3] / 1e6,
        100.0 * rates[3] / rates[0]
    );
    anyhow::ensure!(
        rates[3] > 0.5e6,
        "engine should sustain >0.5M events/s at 2000 testers"
    );
    anyhow::ensure!(
        rates[3] > rates[0] * 0.4,
        "event rate must not collapse with scale"
    );
    println!("§5 scalability claim holds on this substrate");
    Ok(())
}
