//! E12 — the §1/§5 empirical performance models: fit RT(load) and
//! TPut(load) from one run, validate on unseen seeds, and answer the
//! scheduler's QoS query.

use diperf::experiment::presets;
use diperf::experiments::run_with_analysis;
use diperf::predict::PerfModel;

fn main() -> anyhow::Result<()> {
    println!("# E12 / §1 — empirical performance model\n");
    let train = run_with_analysis(&presets::prews_fig3(42));
    let model = PerfModel::fit(&train.out);

    println!(
        "fitted over load [{:.1}, {:.1}]; rt rms {:.3} s; knee {:?}",
        model.load_range.0, model.load_range.1, model.rt_rms, model.knee
    );
    println!("\nload -> predicted rt / tput:");
    for load in [5.0, 15.0, 33.0, 60.0, 88.0] {
        println!(
            "  {load:>5.0}  {:>8.2} s  {:>7.2} jobs/quantum",
            model.predict_rt(load),
            model.predict_tput(load)
        );
    }

    // cross-seed validation (the paper's §5 'validate them' future work)
    println!("\ncross-seed validation (mean relative rt error):");
    let mut worst: f64 = 0.0;
    for seed in [7u64, 1234, 999] {
        let test = run_with_analysis(&presets::prews_fig3(seed));
        let err = model.validation_error(
            &test.out.load,
            &test.out.rt_mean,
            &test.out.tput,
        );
        worst = worst.max(err);
        println!("  seed {seed:>6}: {:.1}%", err * 100.0);
    }

    // monotonicity + QoS sanity
    anyhow::ensure!(
        model.predict_rt(60.0) > model.predict_rt(10.0),
        "rt model must grow with load"
    );
    let qos = model.max_load_for_rt(10.0);
    println!("\nQoS: rt <= 10 s admits up to {qos:?} concurrent clients");
    anyhow::ensure!(qos.is_some(), "QoS query must be answerable");
    anyhow::ensure!(
        worst < 0.35,
        "model must transfer across seeds (worst {:.1}%)",
        worst * 100.0
    );
    println!("\n§1 predictive-model claim holds (worst error {:.1}%)",
        worst * 100.0);
    Ok(())
}
