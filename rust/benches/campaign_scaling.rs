//! Campaign fan-out benchmark: the smoke grid at `--jobs 1` vs `--jobs
//! N`, with the byte-identity contract checked on real hardware and
//! the speedup appended to `BENCH_scale.json` — the campaign layer's
//! claim is "simulator speed scales with cores", so the trajectory
//! artifact must track it (the Ingo & Daly lesson again).
//!
//! Grid size: `DIPERF_CAMPAIGN_LOADS=3,6,9` overrides the load axis
//! (CI smoke keeps the default).

use diperf::bench_util::{append_scale_rows, scale_json, upsert_scale_field};
use diperf::campaign::{self, report};

fn main() -> anyhow::Result<()> {
    let mut spec = campaign::spec::by_name("campaign_smoke", 42)?;
    if let Ok(loads) = std::env::var("DIPERF_CAMPAIGN_LOADS") {
        spec.loads = loads
            .split(',')
            .filter_map(|x| x.trim().parse().ok())
            .collect();
        spec.validate()?;
    }
    let jobs = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "# campaign fan-out: {} cells, jobs 1 vs {jobs}\n",
        spec.num_cells()
    );

    let serial = campaign::run(&spec, 1)?;
    let parallel = campaign::run(&spec, jobs)?;

    // the determinism contract, on whatever machine runs this bench
    let csv1 = report::comparison_csv(&serial.cells);
    let csvn = report::comparison_csv(&parallel.cells);
    anyhow::ensure!(csv1 == csvn, "comparison CSV differs across job counts");
    anyhow::ensure!(
        report::load_response_csv(&serial.spec, &serial.cells)
            == report::load_response_csv(&parallel.spec, &parallel.cells),
        "load-response CSV differs across job counts"
    );
    anyhow::ensure!(
        report::model_error_csv(&serial.models)
            == report::model_error_csv(&parallel.models),
        "model-error CSV differs across job counts"
    );

    let speedup = serial.wall_s / parallel.wall_s.max(1e-9);
    println!(
        "jobs 1: {:.2}s   jobs {jobs}: {:.2}s   speedup {speedup:.2}x",
        serial.wall_s, parallel.wall_s
    );
    for m in &parallel.models {
        println!(
            "model {}: held-out rt MAE {:.3}s rel {:.1}%",
            m.service,
            m.err.mae_s,
            m.err.rel * 100.0
        );
    }

    // One shared row builder (Campaign::bench_row) keeps this bench and
    // `diperf campaign --bench-json` emitting identical row shapes.
    let rows = [serial.bench_row(), parallel.bench_row()];
    let summary = [
        ("campaign_speedup", format!("{speedup:.3}")),
        ("campaign_jobs", format!("{jobs}")),
    ];
    let doc = match std::fs::read_to_string("BENCH_scale.json") {
        Ok(existing) => {
            // set the summary fields whatever they hold (null, a
            // previous run's value, or absent in the fresh per-run
            // documents CI starts from), then append the fresh rows
            let mut patched = existing.clone();
            for (k, v) in &summary {
                if let Some(p) = upsert_scale_field(&patched, k, v) {
                    patched = p;
                }
            }
            match append_scale_rows(&patched, &rows) {
                Some(doc) => doc,
                None => {
                    // same contract as bench_util::append_or_init: the
                    // accumulated rows are the perf trajectory, so an
                    // unrecognizable document is preserved, not rebuilt
                    std::fs::write("BENCH_scale.json.bak", &existing)?;
                    anyhow::bail!(
                        "BENCH_scale.json has no recognizable \"rows\" \
                         array; refusing to overwrite the perf trajectory \
                         (original preserved as BENCH_scale.json.bak)"
                    );
                }
            }
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            scale_json(&rows, &summary)
        }
        Err(e) => return Err(e.into()),
    };
    std::fs::write("BENCH_scale.json", doc)?;
    println!("\nappended campaign rows to BENCH_scale.json");

    // Guard only where it is meaningful: with 2+ real cores and 6 cells
    // the fan-out must beat serial by a sane margin.  (Single-core CI
    // runners skip it.)
    if jobs >= 2 {
        anyhow::ensure!(
            speedup >= 1.1,
            "campaign fan-out gained nothing: {speedup:.2}x on {jobs} cores"
        );
    }
    Ok(())
}
