//! E3 — regenerates Figure 5 (pre-WS GRAM: average aggregate load vs
//! jobs completed per machine; bubble area = completions).  The paper's
//! signature: "the first few machines (as well as the last few) have a
//! lower average aggregate load ... and hence had more jobs completed."

use diperf::experiment::presets;
use diperf::experiments::run_with_analysis;
use diperf::report::{per_client_csv, RunDir};

fn main() -> anyhow::Result<()> {
    println!("# E3 / Figure 5 — pre-WS GRAM load vs completions per machine\n");
    // completions across the WHOLE run (not just the peak window) expose
    // the ramp-edge advantage the paper describes
    let mut cfg = presets::prews_fig3(42);
    cfg.controller.desc.duration_s = 3600.0;
    let run = run_with_analysis(&cfg);
    let d = &run.result.data;

    // per-tester totals over the whole run, from the raw samples
    let n = d.testers.len();
    let mut done = vec![0u64; n];
    for s in &d.samples {
        if s.outcome.ok() {
            done[s.tester.index()] += 1;
        }
    }
    // edge machines (first/last 10 by start order) vs core machines
    let edge: Vec<u64> = done[..10]
        .iter()
        .chain(&done[n - 10..])
        .cloned()
        .collect();
    let core: Vec<u64> = done[n / 2 - 10..n / 2 + 10].to_vec();
    let edge_mean = edge.iter().sum::<u64>() as f64 / edge.len() as f64;
    let core_mean = core.iter().sum::<u64>() as f64 / core.len() as f64;
    println!("mean completions, ramp-edge machines: {edge_mean:.0}");
    println!("mean completions, mid-ramp machines:  {core_mean:.0}");
    println!(
        "edge advantage: {:.2}x (paper: edge machines 'had more jobs \
         completed')",
        edge_mean / core_mean.max(1.0)
    );

    let dir = RunDir::create("bench_out", "fig5")?;
    dir.write("fig5_bubble.csv", &per_client_csv(&run.out, d))?;
    println!("\nseries -> bench_out/fig5/fig5_bubble.csv");

    anyhow::ensure!(
        edge_mean > core_mean * 1.1,
        "ramp-edge machines must complete more jobs (edge {edge_mean:.0} \
         vs core {core_mean:.0})"
    );
    println!("figure 5 shape OK");
    Ok(())
}
