//! Scenario-engine integration tests: determinism under faults, the
//! churn end-to-end run, and property tests for scenario + analysis
//! invariants (the framework is only trustworthy once its own hostile
//! runs are reproducible).

use diperf::analysis::{self, AnalysisInput};
use diperf::cli;
use diperf::experiment::{presets, run_experiment};
use diperf::scenario::{Action, ScenarioEvent};
use diperf::util::proptest::{forall, prop};

/// The determinism contract, checked field by field: two runs of the
/// same config + seed must produce bit-identical `RunData`.
fn assert_bit_identical(a: &diperf::metrics::RunData, b: &diperf::metrics::RunData) {
    assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
    assert_eq!(a.dropped_unsynced, b.dropped_unsynced);
    assert_eq!(a.samples.len(), b.samples.len());
    for (x, y) in a.samples.iter().zip(&b.samples) {
        assert_eq!(x.tester, y.tester);
        assert_eq!(x.seq, y.seq);
        assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
        assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        assert_eq!(x.rt.to_bits(), y.rt.to_bits());
        assert_eq!(x.outcome, y.outcome);
    }
    assert_eq!(a.testers.len(), b.testers.len());
    for (x, y) in a.testers.iter().zip(&b.testers) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.started_at.to_bits(), y.started_at.to_bits());
        assert_eq!(x.stopped_at.to_bits(), y.stopped_at.to_bits());
        assert_eq!(x.evicted, y.evicted);
        assert_eq!(x.samples, y.samples);
        assert_eq!(x.rejoins, y.rejoins);
    }
}

#[test]
fn churn_run_is_bit_identical_per_seed() {
    // prews_fig3 scaled down, with the shipped churn scenario on top
    let cfg = presets::churn_study(12, 300.0, 42);
    let a = run_experiment(&cfg);
    let b = run_experiment(&cfg);
    assert_eq!(a.events, b.events);
    assert_eq!(a.faults, b.faults);
    assert_bit_identical(&a.data, &b.data);

    // a different seed genuinely changes the run
    let c = run_experiment(&presets::churn_study(12, 300.0, 43));
    assert_ne!(
        a.data.samples.len(),
        c.data.samples.len(),
        "different seeds should produce different runs"
    );
}

#[test]
fn killing_a_third_of_testers_mid_run_completes_and_dips() {
    let mut cfg = presets::prews_small(12, 600.0, 7);
    cfg.controller.silence_timeout_s = 60.0;
    cfg.scenario.timeline = vec![ScenarioEvent {
        at_s: 300.0,
        action: Action::CrashTesters {
            frac: 0.3,
            restart_after_s: None, // permanent: the paper's dead nodes
        },
    }];
    let r = run_experiment(&cfg);
    assert_eq!(r.faults, 4, "ceil(0.3 * 12) permanent crashes");

    // the controller notices: the silent testers are evicted
    let evicted = r.data.testers.iter().filter(|t| t.evicted).count();
    assert!(evicted >= 4, "evicted {evicted}");

    // fewer distinct active clients in the affected quanta
    let churn = analysis::churn_report(&r.data, 64);
    let quantum = r.data.duration_s.max(1.0) / 64.0;
    let window_mean = |lo: f64, hi: f64| {
        let vals: Vec<f64> = (0..64)
            .filter(|&b| {
                let t = (b as f64 + 0.5) * quantum;
                t >= lo && t <= hi
            })
            .map(|b| churn.active[b])
            .collect();
        vals.iter().sum::<f64>() / vals.len().max(1) as f64
    };
    let pre = window_mean(150.0, 290.0);
    let post = window_mean(350.0, 550.0);
    assert!(
        post <= pre - 3.0,
        "active clients did not drop: pre {pre:.1} post {post:.1}"
    );

    // the run still completes and produces data after the crash
    assert!(r.data.samples.iter().any(|s| s.t_end > 400.0));
    assert!(r.data.completed() > 500);
}

#[test]
fn prop_evicted_testers_never_report_after_eviction() {
    forall(3, |rng| {
        let seed = rng.next_u64();
        let mut cfg = presets::churn_study(8, 240.0, seed);
        // most crashes permanent so evictions actually stick
        cfg.scenario.churn.as_mut().expect("churn preset").restart_prob = 0.3;
        cfg.scenario.churn.as_mut().expect("churn preset").crash_rate_per_hour = 20.0;
        let r = run_experiment(&cfg);
        for t in r.data.testers.iter().filter(|t| t.evicted) {
            // 5 s margin absorbs clock-reconciliation error
            let after = r
                .data
                .samples
                .iter()
                .filter(|s| s.tester == t.id && s.t_end > t.stopped_at + 5.0)
                .count();
            if after > 0 {
                return Err(format!(
                    "tester {} reported {after} samples after eviction (seed {seed})",
                    t.id
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_binned_throughput_equals_per_client_sum() {
    forall(3, |rng| {
        let seed = rng.next_u64();
        let cfg = presets::churn_study(8, 240.0, seed);
        let r = run_experiment(&cfg);
        let inp = AnalysisInput::from_run(&r.data, 128, 20.0);
        let out = analysis::analyze(&inp, 128, 16);
        let binned: f64 = out.tput.iter().sum();
        let mut per_client = vec![0.0f64; 16];
        for s in &r.data.samples {
            if s.outcome.ok() {
                per_client[s.tester.index()] += 1.0;
            }
        }
        let by_client: f64 = per_client.iter().sum();
        if binned != by_client {
            return Err(format!(
                "binned {binned} != per-client sum {by_client} (seed {seed})"
            ));
        }
        prop(
            binned == r.data.completed() as f64,
            &format!("binned {binned} != completed {} (seed {seed})", r.data.completed()),
        )
    });
}

#[test]
fn prop_fairness_and_availability_bounded() {
    forall(3, |rng| {
        let seed = rng.next_u64();
        let cfg = presets::spike_study(10, 300.0, seed);
        let r = run_experiment(&cfg);
        let c = analysis::churn_report(&r.data, 64);
        if !(0.0..=1.0).contains(&c.jain_fairness) {
            return Err(format!("jain {} out of [0,1] (seed {seed})", c.jain_fairness));
        }
        for (b, &a) in c.availability.iter().enumerate() {
            if !(0.0..=1.0).contains(&a) {
                return Err(format!("availability[{b}] = {a} (seed {seed})"));
            }
        }
        if c.min_availability > c.mean_availability + 1e-12 {
            return Err(format!(
                "min {} > mean {} (seed {seed})",
                c.min_availability, c.mean_availability
            ));
        }
        let inp = AnalysisInput::from_run(&r.data, 64, 20.0);
        let out = analysis::analyze(&inp, 64, 16);
        for (i, &u) in out.util.iter().enumerate() {
            if !(0.0..=1.0 + 1e-9).contains(&u) {
                return Err(format!("util[{i}] = {u} (seed {seed})"));
            }
        }
        Ok(())
    });
}

#[test]
fn cli_spike_preset_emits_availability_report() {
    let dir = std::env::temp_dir().join(format!(
        "diperf_scn_cli_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("spikerun");
    let argv: Vec<String> = [
        "run", "--preset", "spike_study", "--testers", "8", "--seed", "5",
        "--out", out.to_str().unwrap(), "--native", "--quiet",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(cli::main(&argv).unwrap(), 0);
    let avail =
        std::fs::read_to_string(out.join("fig_availability.csv")).unwrap();
    assert!(avail.starts_with("time_s,active_clients,availability\n"));
    assert!(avail.trim().lines().count() > 10);
    let summary = std::fs::read_to_string(out.join("summary.txt")).unwrap();
    assert!(summary.contains("scenario faults"), "summary: {summary}");
    assert!(summary.contains("availability"), "summary: {summary}");
    std::fs::remove_dir_all(&dir).ok();
}
