//! End-to-end tests for the E-Divisive perf gate: synthetic-shift
//! detection accuracy, the null-series false-positive bound, bench
//! writer → ingester round-trips, the checked-in perf-gate fixture, and
//! the `diperf analyze changepoints` CLI surface.

use diperf::analysis::changepoint::{
    fresh_regressions, is_fresh, metric_polarity, report_csv, Detector,
    Polarity, SeriesSet,
};
use diperf::bench_util::{scale_json, ScaleRow};
use diperf::util::Pcg64;

fn fixture(name: &str) -> String {
    format!(
        "{}/rust/tests/fixtures/perf_gate/{name}",
        env!("CARGO_MANIFEST_DIR")
    )
}

/// The acceptance criterion: a mean shift injected at index 25 of a
/// 50-point series is found at the correct index ±1.
#[test]
fn injected_shift_on_50_points_is_located_within_one_index() {
    let mut rng = Pcg64::seed_from(1234);
    for (shift_at, lo, hi, noise) in
        [(25usize, 100.0, 130.0, 4.0), (25, 1.0e6, 0.8e6, 0.02e6)]
    {
        let xs: Vec<f64> = (0..50)
            .map(|i| {
                let base = if i < shift_at { lo } else { hi };
                base + rng.uniform(-noise, noise)
            })
            .collect();
        let cps = Detector::default().detect(&xs);
        assert!(!cps.is_empty(), "shift {lo}->{hi} not detected");
        assert!(
            cps.iter().any(|c| (c.index as i64 - shift_at as i64).abs() <= 1),
            "shift {lo}->{hi} located at {:?}, wanted {shift_at}±1",
            cps.iter().map(|c| c.index).collect::<Vec<_>>()
        );
    }
}

/// The false-positive bound: pure-noise series must yield zero
/// detections (several independent draws, not just one lucky seed).
#[test]
fn null_series_yield_zero_detections() {
    let det = Detector::default();
    for seed in [2u64, 3, 5, 8, 13] {
        let mut rng = Pcg64::seed_from(seed);
        let xs: Vec<f64> = (0..50).map(|_| rng.uniform(95.0, 105.0)).collect();
        let cps = det.detect(&xs);
        assert!(
            cps.is_empty(),
            "seed {seed}: spurious changepoints {:?}",
            cps.iter().map(|c| (c.index, c.p_value)).collect::<Vec<_>>()
        );
    }
}

/// Round-trip: the exact document `bench_scale` writes parses through
/// the ingester with every metric value intact.
#[test]
fn bench_writer_output_round_trips_through_the_ingester() {
    let rows = vec![
        ScaleRow {
            label: "churn-1000-wheel".into(),
            testers: 1000,
            queue: "wheel",
            collection: "stream",
            virtual_s: 300.0,
            wall_s: 1.2579,
            events: 4_000_000,
            events_per_sec: 3_180_000.0,
            peak_pending: 2048,
            peak_rss_kb: 51200,
            samples: 250_000,
        },
        ScaleRow {
            label: "churn-1000-heap".into(),
            testers: 1000,
            queue: "heap",
            collection: "stream",
            virtual_s: 300.0,
            wall_s: 2.5,
            events: 4_000_000,
            events_per_sec: 1_600_000.0,
            peak_pending: 4096,
            peak_rss_kb: 64000,
            samples: 250_000,
        },
    ];
    let doc = scale_json(
        &rows,
        &[
            ("note", "\"round trip\"".into()),
            ("wheel_vs_heap_experiment", "1.988".into()),
            ("campaign_speedup", "null".into()),
        ],
    );
    let mut set = SeriesSet::new();
    set.ingest_scale_json(&doc).unwrap();
    assert_eq!(set.docs, 1);
    for r in &rows {
        assert_eq!(set.series[&format!("{}/wall_s", r.label)], vec![r.wall_s]);
        assert_eq!(
            set.series[&format!("{}/events_per_sec", r.label)],
            vec![r.events_per_sec]
        );
        assert_eq!(
            set.series[&format!("{}/peak_pending", r.label)],
            vec![r.peak_pending as f64]
        );
        assert_eq!(
            set.series[&format!("{}/peak_rss_kb", r.label)],
            vec![r.peak_rss_kb as f64]
        );
    }
    assert_eq!(set.series["summary/wheel_vs_heap_experiment"], vec![1.988]);
    assert!(!set.series.contains_key("summary/campaign_speedup"));
}

/// The checked-in CI fixture: the healthy history alone is quiet; with
/// the injected-regression document appended, the throughput collapse
/// is found at the regime boundary, classified as a fresh regression.
#[test]
fn perf_gate_fixture_flags_the_injected_regression() {
    // healthy history only: no alarms on any series
    let mut healthy = SeriesSet::new();
    healthy.ingest_path(&fixture("history_good.json")).unwrap();
    let det = Detector::default();
    let findings = det.detect_all(&healthy);
    assert!(findings.iter().all(|f| f.changepoints.is_empty()));
    assert!(fresh_regressions(&findings, 5).is_empty());

    // healthy + regression: the gate trips
    let mut set = SeriesSet::new();
    set.ingest_path(&fixture("history_good.json")).unwrap();
    set.ingest_path(&fixture("history_regression.json")).unwrap();
    let eps = &set.series["churn-1000-wheel/events_per_sec"];
    assert_eq!(eps.len(), 13, "10 good + 3 regressed points");
    let findings = det.detect_all(&set);
    let fresh = fresh_regressions(&findings, 5);
    assert!(!fresh.is_empty(), "regression not flagged");
    let (f, c) = fresh
        .iter()
        .find(|(f, _)| f.key == "churn-1000-wheel/events_per_sec")
        .expect("throughput series must trip the gate");
    assert!((c.index as i64 - 10).abs() <= 1, "index {}", c.index);
    assert!(c.before_mean > c.after_mean);
    assert!(is_fresh(c, f.n, 5));
    assert_eq!(metric_polarity(&f.key), Polarity::HigherIsBetter);

    // the CSV report carries the alarm
    let csv = report_csv(&findings, 5);
    assert!(csv.lines().next().unwrap().starts_with("series,n,index"));
    let alarm = csv
        .lines()
        .find(|l| l.starts_with("churn-1000-wheel/events_per_sec"))
        .expect("alarm row");
    assert!(alarm.ends_with("down,true,true"), "{alarm}");
}

/// The CLI surface: `diperf analyze changepoints` over the fixtures
/// exits 0 on the healthy history and 2 with `--fail-on-fresh` once
/// the regression document lands, writing the report both times.
#[test]
fn cli_gate_exits_by_verdict() {
    let tmp = std::env::temp_dir().join(format!(
        "diperf_cp_cli_{}.csv",
        std::process::id()
    ));
    let out = tmp.to_str().unwrap().to_string();
    let sv = |xs: &[&str]| -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    };

    let code = diperf::cli::main(&sv(&[
        "analyze",
        "changepoints",
        &fixture("history_good.json"),
        "--fail-on-fresh",
        "--out",
        &out,
    ]))
    .unwrap();
    assert_eq!(code, 0, "healthy history must pass the gate");

    let code = diperf::cli::main(&sv(&[
        "analyze",
        "changepoints",
        &fixture("history_good.json"),
        &fixture("history_regression.json"),
        "--fail-on-fresh",
        "--out",
        &out,
    ]))
    .unwrap();
    assert_eq!(code, 2, "regression history must fail the gate");
    let report = std::fs::read_to_string(&tmp).unwrap();
    assert!(report.contains("churn-1000-wheel/events_per_sec"));
    std::fs::remove_file(&tmp).ok();

    // without --fail-on-fresh the same history reports but passes
    let code = diperf::cli::main(&sv(&[
        "analyze",
        "changepoints",
        &fixture("history_good.json"),
        &fixture("history_regression.json"),
        "--out",
        &out,
    ]))
    .unwrap();
    assert_eq!(code, 0);
    std::fs::remove_file(&tmp).ok();
}
