//! The collection-mode contract: for a fixed seed, a streaming run and
//! a retained run (analyzed on the same pre-declared grid) must produce
//! the same figures — under either event queue.
//!
//! What "the same" means, precisely: the simulation itself is
//! bit-identical (collection is an observer), so every counting series
//! (throughput bins, per-client completions, availability) matches
//! exactly; floating *sums* (offered load, response-time totals) differ
//! only in summation order, so they match to rounding; the rendered
//! figure CSVs therefore agree at print precision.

use diperf::analysis::{self, AnalysisInput};
use diperf::experiment::{presets, run_experiment_opts, RunOptions};
use diperf::metrics::CollectionMode;
use diperf::report;
use diperf::sim::QueueKind;

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn assert_series_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(close(*x, *y, tol), "{what}[{i}]: {x} vs {y}");
    }
}

/// Compare two CSVs cell by cell: numeric cells to a relative
/// tolerance, everything else (headers, labels) exactly.
fn assert_csv_close(a: &str, b: &str, tol: f64, what: &str) {
    let la: Vec<&str> = a.lines().collect();
    let lb: Vec<&str> = b.lines().collect();
    assert_eq!(la.len(), lb.len(), "{what}: row count");
    for (ra, rb) in la.iter().zip(&lb) {
        let ca: Vec<&str> = ra.split(',').collect();
        let cb: Vec<&str> = rb.split(',').collect();
        assert_eq!(ca.len(), cb.len(), "{what}: column count in {ra:?}");
        for (x, y) in ca.iter().zip(&cb) {
            match (x.parse::<f64>(), y.parse::<f64>()) {
                (Ok(xv), Ok(yv)) => {
                    assert!(close(xv, yv, tol), "{what}: {x} vs {y} in {ra:?}")
                }
                _ => assert_eq!(x, y, "{what}: non-numeric cell"),
            }
        }
    }
}

#[test]
fn figures_agree_at_100_testers_under_both_queues() {
    // 100 testers under churn — the acceptance configuration: crashes,
    // rejoins and evictions all in play
    let cfg = presets::churn_study(100, 120.0, 1234);
    for queue in [QueueKind::Wheel, QueueKind::Heap] {
        let retain = run_experiment_opts(
            &cfg,
            RunOptions {
                queue,
                ..RunOptions::default()
            },
        );
        let stream = run_experiment_opts(
            &cfg,
            RunOptions {
                queue,
                collect: CollectionMode::Stream,
                ..RunOptions::default()
            },
        );
        // the simulation is identical; only collection differs
        assert_eq!(retain.events, stream.events, "{queue:?}");
        assert_eq!(
            retain.data.dropped_unsynced, stream.data.dropped_unsynced,
            "{queue:?}"
        );
        assert_eq!(retain.faults, stream.faults);

        // post-hoc analysis on the same pre-declared grid streaming used
        let grid = retain.grid;
        let inp = AnalysisInput::from_grid(&retain.data, &grid);
        let posthoc = analysis::analyze(&inp, grid.num_quanta, grid.num_clients);
        let agg = stream.stream.as_ref().expect("streaming aggregator");
        let streamed = analysis::output_from_binned(&agg.binned);

        // counting series and their exact-arithmetic derivatives match
        // bit-for-bit regardless of aggregation order
        assert_eq!(posthoc.tput, streamed.tput, "{queue:?} tput");
        assert_eq!(posthoc.completed, streamed.completed, "{queue:?} completed");
        assert_eq!(posthoc.util, streamed.util, "{queue:?} util");
        assert_eq!(posthoc.fairness, streamed.fairness, "{queue:?} fairness");
        assert_eq!(
            posthoc.active_time, streamed.active_time,
            "{queue:?} active_time"
        );
        assert_eq!(posthoc.totals[0], streamed.totals[0], "completions");
        assert_eq!(posthoc.totals[1], streamed.totals[1], "failures");
        assert_eq!(posthoc.totals[5], streamed.totals[5], "max rt");

        // floating sums match to summation-order rounding
        assert_series_close(&posthoc.load, &streamed.load, 1e-9, "load");
        assert_series_close(&posthoc.rt_mean, &streamed.rt_mean, 1e-9, "rt_mean");
        assert_series_close(&posthoc.rt_ma, &streamed.rt_ma, 1e-9, "rt_ma");
        assert_series_close(&posthoc.load_ma, &streamed.load_ma, 1e-9, "load_ma");
        assert_eq!(posthoc.tput_ma, streamed.tput_ma, "tput_ma exact");
        for frac in [0.1, 0.5, 0.9] {
            let t = frac * grid.duration;
            let a = posthoc.poly_rt_at(t, grid.t0, grid.duration);
            let b = streamed.poly_rt_at(t, grid.t0, grid.duration);
            assert!(close(a, b, 1e-6), "poly rt at {t}: {a} vs {b}");
        }

        // churn views: identical activity, fairness to rounding
        let cr = analysis::churn_report_grid(&retain.data, &grid);
        let cs = analysis::churn_from_stream(agg, &stream.data.testers);
        assert_eq!(cr.active, cs.active, "{queue:?} active");
        assert_eq!(cr.evicted, cs.evicted);
        assert_eq!(cr.rejoins, cs.rejoins);
        assert!(close(cr.jain_fairness, cs.jain_fairness, 1e-12));
        assert!(close(cr.mean_availability, cs.mean_availability, 1e-12));
        assert!(close(cr.min_availability, cs.min_availability, 1e-12));

        // and the rendered figure files agree at print precision
        assert_csv_close(
            &report::timeline_csv(&posthoc, grid.t0, grid.quantum),
            &report::timeline_csv(&streamed, grid.t0, grid.quantum),
            1e-2,
            "timeline csv",
        );
        assert_csv_close(
            &report::per_client_csv(&posthoc, &retain.data),
            &report::per_client_csv(&streamed, &stream.data),
            1e-2,
            "per-client csv",
        );
        assert_csv_close(
            &report::churn_csv(&cr, grid.t0, grid.quantum),
            &report::churn_csv(&cs, grid.t0, grid.quantum),
            1e-2,
            "availability csv",
        );
    }
}

#[test]
fn streaming_quantiles_track_the_retained_distribution() {
    let cfg = presets::quick_http(20, 90.0, 7);
    let retain = run_experiment_opts(&cfg, RunOptions::default());
    let stream = run_experiment_opts(
        &cfg,
        RunOptions {
            collect: CollectionMode::Stream,
            ..RunOptions::default()
        },
    );
    let agg = stream.stream.as_ref().unwrap();
    // exact quantiles from the retained samples
    let mut rts: Vec<f64> = retain
        .data
        .samples
        .iter()
        .filter(|s| s.outcome.ok())
        .map(|s| s.rt)
        .collect();
    assert!(rts.len() > 500);
    rts.sort_by(f64::total_cmp);
    let exact = |p: f64| rts[((rts.len() - 1) as f64 * p) as usize];
    let p50 = agg.rt_p50.value();
    let p99 = agg.rt_p99.value();
    assert!(
        close(p50, exact(0.5), 0.15),
        "p50 {p50} vs exact {}",
        exact(0.5)
    );
    assert!(
        close(p99, exact(0.99), 0.25),
        "p99 {p99} vs exact {}",
        exact(0.99)
    );
}

#[test]
fn streaming_buffers_are_bounded_by_the_sync_window() {
    // the controller's pending buffers drain on every sync: after the
    // run nothing is left and the aggregate matches the sample count
    let cfg = presets::quick_http(6, 120.0, 3);
    let retain = run_experiment_opts(&cfg, RunOptions::default());
    let stream = run_experiment_opts(
        &cfg,
        RunOptions {
            collect: CollectionMode::Stream,
            ..RunOptions::default()
        },
    );
    let agg = stream.stream.as_ref().unwrap();
    assert_eq!(
        agg.samples_seen + stream.data.dropped_unsynced,
        retain.data.samples.len() as u64 + retain.data.dropped_unsynced
    );
    // per-tester receipt counters agree with the retained ground truth
    for (a, b) in retain.data.testers.iter().zip(&stream.data.testers) {
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.evicted, b.evicted);
        assert_eq!(a.rejoins, b.rejoins);
    }
}
