//! The queue-equivalence contract: the hierarchical timer wheel and the
//! reference `BinaryHeap` must dispatch *identical* `(time, seq, event)`
//! sequences for any workload — that is what lets the engine swap
//! implementations without perturbing a single seeded replay.
//!
//! Differential tests drive both queues with the same inputs and demand
//! bit-identical outputs; property tests re-state the engine invariants
//! (time order, FIFO ties, monotone clock, horizon stop) per queue.

use diperf::sim::{Engine, QueueKind, SimTime};
use diperf::util::proptest::{forall, prop};
use diperf::util::Pcg64;

const KINDS: [QueueKind; 2] = [QueueKind::Heap, QueueKind::Wheel];

fn drain(eng: &mut Engine<u64>) -> Vec<(u64, u64)> {
    std::iter::from_fn(|| eng.next().map(|(t, e)| (t.0, e))).collect()
}

#[test]
fn differential_random_workloads() {
    forall(60, |rng| {
        let n = 1 + rng.next_below(400);
        // times mixing single-slot clusters, near horizon, far horizon
        // and overflow territory, with plenty of exact duplicates
        let times: Vec<u64> = (0..n)
            .map(|_| match rng.next_below(5) {
                0 => rng.next_below(1_000),
                1 => rng.next_below(1_000_000),
                2 => rng.next_below(100_000_000),
                3 => rng.next_below(100_000_000_000),
                _ => 777 * rng.next_below(4), // heavy duplicates
            })
            .collect();
        let mut heap: Engine<u64> = Engine::with_queue(QueueKind::Heap);
        let mut wheel: Engine<u64> = Engine::with_queue(QueueKind::Wheel);
        for (i, &t) in times.iter().enumerate() {
            heap.schedule(SimTime(t), i as u64);
            wheel.schedule(SimTime(t), i as u64);
        }
        prop(
            drain(&mut heap) == drain(&mut wheel),
            "dispatch sequences diverged",
        )
    });
}

#[test]
fn differential_interleaved_push_pop() {
    // pops interleaved with pushes relative to the current clock — the
    // wheel's watermark logic is most at risk exactly here
    forall(40, |rng| {
        let ops: Vec<u64> = (0..300).map(|_| rng.next_below(1 << 20)).collect();
        let run = |kind: QueueKind| {
            let mut eng: Engine<u64> = Engine::with_queue(kind);
            let mut seen = Vec::new();
            for (i, &d) in ops.iter().enumerate() {
                // schedule relative to "now", sometimes pop
                eng.schedule(eng.now() + diperf::sim::SimDuration(d), i as u64);
                if i % 3 == 0 {
                    if let Some((t, e)) = eng.next() {
                        seen.push((t.0, e));
                    }
                }
            }
            while let Some((t, e)) = eng.next() {
                seen.push((t.0, e));
            }
            seen
        };
        prop(
            run(QueueKind::Heap) == run(QueueKind::Wheel),
            "interleaved sequences diverged",
        )
    });
}

#[test]
fn differential_cascading_workload() {
    // handler-driven: each event schedules a successor at a random
    // delta — the tester-launch-loop shape, including far-future jumps
    let run = |kind: QueueKind| -> Vec<(u64, u32)> {
        let mut rng = Pcg64::seed_from(99);
        let mut eng: Engine<u32> = Engine::with_queue(kind);
        for i in 0..50 {
            eng.schedule(SimTime(rng.next_below(10_000)), i);
        }
        let mut seen = Vec::new();
        let mut budget = 20_000u32;
        eng.run_until(SimTime(u64::MAX / 2), |eng, t, e| {
            seen.push((t.0, e));
            if budget > 0 {
                budget -= 1;
                let d = rng.next_below(50_000_000); // up to 50 s ahead
                eng.schedule(SimTime(t.0 + d), e.wrapping_add(1));
            }
        });
        seen
    };
    let heap = run(QueueKind::Heap);
    let wheel = run(QueueKind::Wheel);
    assert_eq!(heap.len(), wheel.len());
    assert_eq!(heap, wheel);
}

#[test]
fn time_order_property_per_queue() {
    for kind in KINDS {
        forall(30, |rng| {
            let mut eng: Engine<u64> = Engine::with_queue(kind);
            for i in 0..300 {
                eng.schedule(SimTime(rng.next_below(1 << 40)), i);
            }
            let seq = drain(&mut eng);
            prop(
                seq.windows(2).all(|w| w[0].0 <= w[1].0),
                "time order violated",
            )
        });
    }
}

#[test]
fn fifo_ties_survive_partial_drains() {
    for kind in KINDS {
        forall(30, |rng| {
            let t = 1_000 + rng.next_below(1_000_000);
            let mut eng: Engine<u64> = Engine::with_queue(kind);
            for i in 0..20 {
                eng.schedule(SimTime(t), i);
            }
            let mut got = Vec::new();
            for _ in 0..5 {
                got.push(eng.next().expect("pending").1);
            }
            // same-time events added after a partial drain still follow
            for i in 20..30u64 {
                eng.schedule(SimTime(t), i);
            }
            while let Some((_, e)) = eng.next() {
                got.push(e);
            }
            prop(got == (0..30).collect::<Vec<u64>>(), "FIFO tie broken")
        });
    }
}

#[test]
fn horizon_stop_and_drained_clock_per_queue() {
    for kind in KINDS {
        let mut eng: Engine<u32> = Engine::with_queue(kind);
        eng.schedule(SimTime::from_secs_f64(1.0), 1);
        eng.schedule(SimTime::from_secs_f64(100.0), 2);
        let mut seen = Vec::new();
        eng.run_until(SimTime::from_secs_f64(10.0), |_, _, e| seen.push(e));
        assert_eq!(seen, vec![1], "{kind:?}");
        assert_eq!(eng.now(), SimTime::from_secs_f64(10.0));
        assert_eq!(eng.pending(), 1);
        // continue to quiescence past the event, clock lands on horizon
        eng.run_until(SimTime::from_secs_f64(500.0), |_, _, e| seen.push(e));
        assert_eq!(seen, vec![1, 2]);
        assert_eq!(eng.now(), SimTime::from_secs_f64(500.0), "{kind:?}");
    }
}

#[test]
fn wheel_handles_quiescent_far_jumps() {
    // long silences between bursts force the wheel through whole empty
    // frames and the overflow rebase path
    let mut eng: Engine<u32> = Engine::with_queue(QueueKind::Wheel);
    let hours = [0u64, 1, 7, 50]; // microsecond epochs hours apart
    for (i, h) in hours.iter().enumerate() {
        eng.schedule(SimTime(h * 3_600_000_000), i as u32);
    }
    let got: Vec<u32> =
        std::iter::from_fn(|| eng.next().map(|(_, e)| e)).collect();
    assert_eq!(got, vec![0, 1, 2, 3]);
    // scheduling resumes normally after the jumps
    eng.schedule(eng.now() + diperf::sim::SimDuration::from_secs(1), 99);
    assert_eq!(eng.next().map(|(_, e)| e), Some(99));
}
