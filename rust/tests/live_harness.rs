//! Live-harness integration tests: real sockets, real clocks, real
//! threads.
//!
//! De-flaking policy: every *correctness* property (sync error inside
//! the Cristian bound, disconnect semantics, pipeline conservation) is
//! asserted exactly and fails fast.  *Timing-derived* bounds — which a
//! stalled CI runner can violate without any bug — go through
//! [`retry_with_deadline`] and re-run the scenario instead of flaking.
//! Tests whose subject matter is wall-clock behaviour itself are
//! `#[ignore]`d by default; CI runs them explicitly with
//! `cargo test --test live_harness -- --ignored`.

use std::net::{Shutdown, TcpListener};
use std::time::{Duration, Instant};

use diperf::live::{
    self, agent::{run_agent, AgentParams, CallMode},
    crossval,
    target::{PsTargetParams, Target, TargetKind},
    timeserver::{sync_exchange, LiveClock, TimeServer},
    wire::{self, WireUp},
    TargetSel,
};
use diperf::timesync::ClockMap;
use diperf::transport::{CtrlMsg, TestDescription};

/// Re-run a timing-sensitive scenario until it passes or `deadline` of
/// wall-clock time is spent.  The closure returns `Err` only for bounds
/// a stalled runner can violate; genuine correctness violations should
/// `panic!` inside it so they fail on the first attempt.
fn retry_with_deadline<F>(deadline: Duration, mut attempt: F)
where
    F: FnMut() -> Result<(), String>,
{
    let t0 = Instant::now();
    let mut tries = 0u32;
    loop {
        tries += 1;
        let err = match attempt() {
            Ok(()) => return,
            Err(e) => e,
        };
        if t0.elapsed() >= deadline {
            panic!("still failing after {tries} attempts over {deadline:?}: {err}");
        }
        eprintln!("[retry] attempt {tries} failed ({err}); retrying");
        std::thread::sleep(Duration::from_millis(200));
    }
}

/// §3.1.2 over a loopback socket: the offset estimate from a real
/// exchange must recover a known skew to within the measured round-trip
/// asymmetry bound (|error| <= rtt/2).
#[test]
fn loopback_sync_error_stays_within_rtt_bound() {
    let epoch = Instant::now();
    let server_clock = LiveClock::anchored(epoch, 0.0, 0.0);
    let mut srv = TimeServer::spawn(server_clock).unwrap();
    // the agent clock is 4242 s ahead; both anchored at the same epoch,
    // so the true offset is exactly -4242
    let skew = 4242.0;
    let clock = LiveClock::anchored(epoch, skew, 0.0);
    let mut conn = std::net::TcpStream::connect(srv.addr).unwrap();
    conn.set_nodelay(true).unwrap();
    for _ in 0..20 {
        let p = sync_exchange(&mut conn, &clock).unwrap();
        let err = (p.offset() - (-skew)).abs();
        assert!(
            err <= p.rtt() / 2.0 + 1e-6,
            "sync error {err} exceeds the rtt/2 bound ({})",
            p.rtt() / 2.0
        );
    }
    srv.shutdown();
}

/// Drift interpolation over >= 3 real sync points: piecewise-linear
/// offsets absorb a 5% frequency error that a single-point map cannot.
///
/// The subject matter here *is* wall-clock behaviour (real sleeps, real
/// round trips), so the test is ignored by default; CI runs it
/// explicitly via `-- --ignored` where a retry still shields it from
/// scheduler stalls.
#[test]
#[ignore = "timing-sensitive: real sleeps and clock reads; CI runs it via -- --ignored"]
fn drift_interpolation_across_real_sync_points() {
    retry_with_deadline(Duration::from_secs(60), || {
        let epoch = Instant::now();
        let mut srv = TimeServer::spawn(LiveClock::anchored(epoch, 0.0, 0.0)).unwrap();
        let skew = 5.0;
        let drift = 0.05; // 5%: huge, so the effect dominates loopback noise
        let clock = LiveClock::anchored(epoch, skew, drift);
        let mut conn = std::net::TcpStream::connect(srv.addr).unwrap();
        conn.set_nodelay(true).unwrap();

        let mut map = ClockMap::new();
        let mut single = ClockMap::new();
        for i in 0..4 {
            let p = sync_exchange(&mut conn, &clock).unwrap();
            map.record(p);
            if i == 0 {
                single.record(p);
            }
            std::thread::sleep(Duration::from_millis(120));
        }
        // a local reading strictly inside the synced range: truth follows
        // from the shared epoch: local = elapsed*(1+drift)+skew
        std::thread::sleep(Duration::from_millis(30));
        let local = clock.now_s();
        let p_last = sync_exchange(&mut conn, &clock).unwrap();
        map.record(p_last);
        let truth = (local - skew) / (1.0 + drift);
        assert!(map.len() >= 3, "need at least 3 sync points, got {}", map.len());
        srv.shutdown();

        // generous CI bound: interpolation error is microseconds on an
        // idle machine, but a stall inside one exchange shows up as
        // rtt/2 asymmetry — retry instead of flaking
        let err = (map.to_global(local).unwrap() - truth).abs();
        if err >= 0.02 {
            return Err(format!("interpolated error {err}s"));
        }
        // the single-point map carries ~5% of >=450 ms of elapsed time;
        // stalls only grow the elapsed time, so this bound is stable
        let err1 = (single.to_global(local).unwrap() - truth).abs();
        if err1 <= 0.010 {
            return Err(format!("single-point error only {err1}s"));
        }
        Ok(())
    });
}

/// The full stack end to end at miniature scale: agents, controller,
/// time server and the in-process target, all over loopback, feeding
/// the same streaming pipeline as the simulator — plus the sim-vs-live
/// crossval report on the identical load spec.
#[test]
fn live_run_end_to_end_with_crossval() {
    retry_with_deadline(Duration::from_secs(90), || {
        let mut cfg = live::live_smoke(11);
        cfg.agents = 3;
        cfg.controller.stagger_s = 0.1;
        cfg.controller.desc.duration_s = 2.0;
        cfg.controller.desc.client_interval_s = 0.04;
        cfg.controller.desc.sync_interval_s = 0.5;
        cfg.grace_s = 1.0;
        let r = live::run_live(&cfg).map_err(|e| format!("run_live: {e:#}"))?;

        // timing-derived bounds first: a stalled runner re-runs
        if r.connected != 3 {
            return Err(format!("only {}/3 agents connected", r.connected));
        }
        if !r.agent_reports.iter().all(|a| a.finished) {
            return Err(format!("unfinished agents: {:?}", r.agent_reports));
        }
        if r.samples() <= 20 {
            return Err(format!("only {} samples", r.samples()));
        }
        if r.stream.binned.total_ok <= 0.0 {
            return Err("no successful calls".into());
        }
        if r.agent_throughput() <= 0.0 {
            return Err("zero agent throughput".into());
        }

        // exact correctness properties: fail fast, never retried
        assert_eq!(r.data.testers.len(), 3);
        assert_eq!(r.data.dropped_unsynced, 0, "first sync precedes first launch");
        let sent: u64 = r.agent_reports.iter().map(|a| a.samples_sent).sum();
        assert_eq!(sent, r.samples(), "every sent sample must be aggregated");
        let st = r.service_stats.expect("in-process target counters");
        assert!(st.completed > 0);
        assert!(
            st.completed >= r.stream.binned.total_ok as u64,
            "agents cannot see more completions than the target served"
        );

        // the same spec through the simulator: generous agreement bound
        let cv = crossval::compare(&cfg, &r).unwrap().expect("in-process twin");
        if cv.divergence >= 0.9 {
            return Err(format!("sim-vs-live throughput divergence {}", cv.divergence));
        }
        let csv = crossval::csv(&cv);
        assert!(csv.starts_with("metric,sim,live,rel_diff\n"), "{csv}");
        assert!(csv.contains("throughput_per_s"));
        assert_eq!(
            crossval::curve_csv(&cv).trim().lines().count(),
            1 + crossval::CURVE_POINTS
        );
        Ok(())
    });
}

/// The reactor backend over real sockets: a two-worker event loop
/// hosting a dozen agents must satisfy the same end-to-end invariants
/// as the thread-per-agent pool (same controller, same wire protocol,
/// same streaming pipeline).
#[cfg(unix)]
#[test]
fn live_run_reactor_backend_end_to_end() {
    retry_with_deadline(Duration::from_secs(90), || {
        let mut cfg = live::live_smoke(17);
        cfg.agents = 12;
        cfg.backend = live::AgentBackend::Reactor;
        cfg.workers = 2;
        cfg.controller.stagger_s = 0.02;
        cfg.controller.desc.duration_s = 2.0;
        cfg.controller.desc.client_interval_s = 0.05;
        cfg.controller.desc.sync_interval_s = 0.5;
        cfg.grace_s = 1.0;
        let r = live::run_live(&cfg).map_err(|e| format!("run_live: {e:#}"))?;

        if r.connected != 12 {
            return Err(format!("only {}/12 reactor agents connected", r.connected));
        }
        if !r.agent_reports.iter().all(|a| a.finished) {
            return Err(format!("unfinished agents: {:?}", r.agent_reports));
        }
        if r.samples() < 50 {
            return Err(format!("only {} samples", r.samples()));
        }
        if r.stream.binned.total_ok <= 0.0 {
            return Err("no successful calls".into());
        }

        // with every agent finished cleanly, queue-time sample counting
        // equals wire-time counting: conservation is exact
        assert_eq!(r.data.testers.len(), 12);
        assert_eq!(r.data.dropped_unsynced, 0, "reactor gates launches on first sync");
        let sent: u64 = r.agent_reports.iter().map(|a| a.samples_sent).sum();
        assert_eq!(sent, r.samples(), "sample conservation across the reactor");
        let st = r.service_stats.expect("in-process target counters");
        assert!(
            st.completed >= r.stream.binned.total_ok as u64,
            "agents cannot see more completions than the target served"
        );
        Ok(())
    });
}

/// The CLI end to end: `diperf live` writes the simulator's report CSV
/// schema plus the crossval reports, enforces `--crossval-bound`, and
/// appends an `agent_throughput` row to the bench trajectory.
#[test]
fn cli_live_writes_reports_and_bench_row() {
    let dir = std::env::temp_dir()
        .join(format!("diperf_live_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("liverun");
    let bench = dir.join("bench.json");
    let argv: Vec<String> = [
        "live", "--preset", "live_smoke", "--agents", "2", "--duration",
        "1.5", "--seed", "3", "--out", out.to_str().unwrap(),
        "--bench-json", bench.to_str().unwrap(), "--crossval-bound",
        "0.95", "--quiet",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    // `--crossval-bound` makes a badly stalled run exit nonzero; that is
    // the CLI doing its job, so re-run rather than flake
    retry_with_deadline(Duration::from_secs(90), || {
        match diperf::cli::main(&argv) {
            Ok(0) => Ok(()),
            Ok(code) => Err(format!("diperf live exited {code}")),
            Err(e) => Err(format!("diperf live failed: {e:#}")),
        }
    });

    // same figure schema as a simulated run, plus the crossval reports
    let timeline =
        std::fs::read_to_string(out.join("fig_timeline.csv")).unwrap();
    assert!(timeline
        .starts_with("time_s,load,load_ma,throughput,throughput_ma,rt_mean_s,rt_ma_s\n"));
    assert!(out.join("fig_per_client.csv").exists());
    assert!(out.join("fig_availability.csv").exists());
    let cv = std::fs::read_to_string(out.join("crossval.csv")).unwrap();
    assert!(cv.starts_with("metric,sim,live,rel_diff\n"), "{cv}");
    assert!(out.join("crossval_curve.csv").exists());
    let summary = std::fs::read_to_string(out.join("summary.txt")).unwrap();
    assert!(summary.contains("agent throughput"), "{summary}");
    assert!(summary.contains("crossval"), "{summary}");
    let json = std::fs::read_to_string(&bench).unwrap();
    assert!(json.contains("agent_throughput"), "{json}");
    assert!(json.contains("\"queue\":\"live\""), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

/// §3 disconnect semantics, live: the agent stops issuing clients the
/// moment its controller session is torn down, orders of magnitude
/// before its 60 s test duration would end.
#[test]
fn agent_stops_the_moment_its_session_drops() {
    retry_with_deadline(Duration::from_secs(60), || {
        let ts = TimeServer::spawn(LiveClock::ideal()).unwrap();
        let target = Target::spawn(
            &TargetKind::Ps(PsTargetParams {
                demand_s: 0.002,
                spread: 1.0 + 1e-9,
                speed: 1.0,
            }),
            3,
        )
        .unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let ctrl_addr = listener.local_addr().unwrap();
        let p = AgentParams {
            id: 0,
            ctrl_addr,
            ts_addr: ts.addr,
            call: CallMode::Framed(target.addr),
            clock: LiveClock::ideal(),
        };
        let agent = std::thread::spawn(move || run_agent(p));

        // controller side of the handshake, by hand
        let (mut sess, _) = listener.accept().unwrap();
        for _ in 0..2 {
            let frame = wire::read_frame(&mut sess).unwrap();
            match wire::decode_up(&frame).unwrap() {
                WireUp::Hello { agent } => assert_eq!(agent, 0),
                WireUp::DeployDone => {}
                other => panic!("unexpected handshake frame {other:?}"),
            }
        }
        let desc = TestDescription {
            duration_s: 60.0,
            client_interval_s: 0.01,
            sync_interval_s: 0.2,
            rate_cap_per_s: f64::INFINITY,
            timeout_s: 5.0,
            give_up_failures: 0,
        };
        wire::write_frame(&mut sess, &wire::encode_ctrl(&CtrlMsg::Start(desc)))
            .unwrap();

        // let it test for a moment, then kill the session without a Stop
        std::thread::sleep(Duration::from_millis(500));
        sess.shutdown(Shutdown::Both).unwrap();
        let t0 = Instant::now();
        let rep = agent.join().unwrap();
        let dt = t0.elapsed().as_secs_f64();

        // exact §3 semantics: a dropped session is reported as such
        assert!(rep.session_dropped, "drop must be reported: {rep:?}");
        assert!(!rep.finished);

        // timing-derived: a stalled runner may not have launched yet,
        // or may be slow to notice the FIN — re-run, don't flake
        if rep.calls == 0 {
            return Err("the agent never got a call off before the kill".into());
        }
        if dt >= 10.0 {
            return Err(format!("agent took {dt}s to notice the dead session"));
        }
        Ok(())
    });
}

/// Controller-side teardown: consecutive-failure eviction closes the
/// session, which stops the agent — the whole run winds down long
/// before the configured duration.
#[test]
fn eviction_drops_sessions_and_ends_the_run_early() {
    retry_with_deadline(Duration::from_secs(120), || {
        // a port with nothing behind it: every probe is ConnectionRefused
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let mut cfg = live::live_smoke(13);
        cfg.agents = 2;
        cfg.controller.stagger_s = 0.05;
        cfg.controller.desc.duration_s = 30.0;
        cfg.controller.desc.client_interval_s = 0.05;
        cfg.controller.desc.sync_interval_s = 0.3;
        cfg.controller.eviction_failures = 2;
        cfg.grace_s = 0.5;
        cfg.target = TargetSel::External(dead_addr.to_string());
        let t0 = Instant::now();
        let r = live::run_live(&cfg).map_err(|e| format!("run_live: {e:#}"))?;
        let dt = t0.elapsed().as_secs_f64();

        // exact semantics: failures evict, failing samples aggregate
        assert!(
            r.data.testers.iter().all(|t| t.evicted),
            "every failing agent must be evicted: {:?}",
            r.data
                .testers
                .iter()
                .map(|t| (t.id, t.evicted))
                .collect::<Vec<_>>()
        );
        assert!(r.samples() > 0, "the failing samples still get aggregated");
        assert_eq!(r.stream.binned.total_ok, 0.0, "nothing can have succeeded");
        // no sim twin exists for an external target
        assert!(crossval::compare(&cfg, &r).unwrap().is_none());

        // timing-derived: early-exit margin vs the 30 s duration
        if dt >= 25.0 {
            return Err(format!("eviction should end the run early, took {dt}s"));
        }
        Ok(())
    });
}
