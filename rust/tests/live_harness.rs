//! Live-harness integration tests: real sockets, real clocks, real
//! threads.
//!
//! Timing assertions are deliberately generous — CI runners stall — but
//! every *correctness* property (sync error inside the Cristian bound,
//! disconnect semantics, pipeline conservation) is exact.

use std::net::{Shutdown, TcpListener};
use std::time::{Duration, Instant};

use diperf::live::{
    self, agent::{run_agent, AgentParams, CallMode},
    crossval,
    target::{PsTargetParams, Target, TargetKind},
    timeserver::{sync_exchange, LiveClock, TimeServer},
    wire::{self, WireUp},
    TargetSel,
};
use diperf::timesync::ClockMap;
use diperf::transport::{CtrlMsg, TestDescription};

/// §3.1.2 over a loopback socket: the offset estimate from a real
/// exchange must recover a known skew to within the measured round-trip
/// asymmetry bound (|error| <= rtt/2).
#[test]
fn loopback_sync_error_stays_within_rtt_bound() {
    let epoch = Instant::now();
    let server_clock = LiveClock::anchored(epoch, 0.0, 0.0);
    let mut srv = TimeServer::spawn(server_clock).unwrap();
    // the agent clock is 4242 s ahead; both anchored at the same epoch,
    // so the true offset is exactly -4242
    let skew = 4242.0;
    let clock = LiveClock::anchored(epoch, skew, 0.0);
    let mut conn = std::net::TcpStream::connect(srv.addr).unwrap();
    conn.set_nodelay(true).unwrap();
    for _ in 0..20 {
        let p = sync_exchange(&mut conn, &clock).unwrap();
        let err = (p.offset() - (-skew)).abs();
        assert!(
            err <= p.rtt() / 2.0 + 1e-6,
            "sync error {err} exceeds the rtt/2 bound ({})",
            p.rtt() / 2.0
        );
    }
    srv.shutdown();
}

/// Drift interpolation over >= 3 real sync points: piecewise-linear
/// offsets absorb a 5% frequency error that a single-point map cannot.
#[test]
fn drift_interpolation_across_real_sync_points() {
    let epoch = Instant::now();
    let mut srv = TimeServer::spawn(LiveClock::anchored(epoch, 0.0, 0.0)).unwrap();
    let skew = 5.0;
    let drift = 0.05; // 5%: huge, so the effect dominates loopback noise
    let clock = LiveClock::anchored(epoch, skew, drift);
    let mut conn = std::net::TcpStream::connect(srv.addr).unwrap();
    conn.set_nodelay(true).unwrap();

    let mut map = ClockMap::new();
    let mut single = ClockMap::new();
    for i in 0..4 {
        let p = sync_exchange(&mut conn, &clock).unwrap();
        map.record(p);
        if i == 0 {
            single.record(p);
        }
        std::thread::sleep(Duration::from_millis(120));
    }
    // a local reading strictly inside the synced range: truth follows
    // from the shared epoch: local = elapsed*(1+drift)+skew
    std::thread::sleep(Duration::from_millis(30));
    let local = clock.now_s();
    let p_last = sync_exchange(&mut conn, &clock).unwrap();
    map.record(p_last);
    let truth = (local - skew) / (1.0 + drift);

    let err = (map.to_global(local).unwrap() - truth).abs();
    assert!(err < 0.005, "interpolated error {err}s");
    // the single-point map carries ~5% of ~450 ms of elapsed time
    let err1 = (single.to_global(local).unwrap() - truth).abs();
    assert!(err1 > 0.010, "single-point error only {err1}s");
    assert!(map.len() >= 3, "need at least 3 sync points, got {}", map.len());
    srv.shutdown();
}

/// The full stack end to end at miniature scale: agents, controller,
/// time server and the in-process target, all over loopback, feeding
/// the same streaming pipeline as the simulator — plus the sim-vs-live
/// crossval report on the identical load spec.
#[test]
fn live_run_end_to_end_with_crossval() {
    let mut cfg = live::live_smoke(11);
    cfg.agents = 3;
    cfg.controller.stagger_s = 0.1;
    cfg.controller.desc.duration_s = 2.0;
    cfg.controller.desc.client_interval_s = 0.04;
    cfg.controller.desc.sync_interval_s = 0.5;
    cfg.grace_s = 1.0;
    let r = live::run_live(&cfg).unwrap();

    assert_eq!(r.connected, 3, "all agents must connect");
    assert_eq!(r.data.testers.len(), 3);
    assert!(r.samples() > 20, "only {} samples", r.samples());
    assert_eq!(r.data.dropped_unsynced, 0, "first sync precedes first launch");
    assert!(
        r.agent_reports.iter().all(|a| a.finished),
        "every agent should finish its duration: {:?}",
        r.agent_reports
    );
    let sent: u64 = r.agent_reports.iter().map(|a| a.samples_sent).sum();
    assert_eq!(sent, r.samples(), "every sent sample must be aggregated");
    assert!(r.stream.binned.total_ok > 0.0, "no successful calls");
    assert!(r.agent_throughput() > 0.0);
    let st = r.service_stats.expect("in-process target counters");
    assert!(st.completed > 0);
    assert!(
        st.completed >= r.stream.binned.total_ok as u64,
        "agents cannot see more completions than the target served"
    );

    // the same spec through the simulator: generous agreement bound
    let cv = crossval::compare(&cfg, &r).unwrap().expect("in-process twin");
    assert!(
        cv.divergence < 0.9,
        "sim-vs-live throughput divergence {}",
        cv.divergence
    );
    let csv = crossval::csv(&cv);
    assert!(csv.starts_with("metric,sim,live,rel_diff\n"), "{csv}");
    assert!(csv.contains("throughput_per_s"));
    assert_eq!(
        crossval::curve_csv(&cv).trim().lines().count(),
        1 + crossval::CURVE_POINTS
    );
}

/// The CLI end to end: `diperf live` writes the simulator's report CSV
/// schema plus the crossval reports, enforces `--crossval-bound`, and
/// appends an `agent_throughput` row to the bench trajectory.
#[test]
fn cli_live_writes_reports_and_bench_row() {
    let dir = std::env::temp_dir()
        .join(format!("diperf_live_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("liverun");
    let bench = dir.join("bench.json");
    let argv: Vec<String> = [
        "live", "--preset", "live_smoke", "--agents", "2", "--duration",
        "1.5", "--seed", "3", "--out", out.to_str().unwrap(),
        "--bench-json", bench.to_str().unwrap(), "--crossval-bound",
        "0.95", "--quiet",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(diperf::cli::main(&argv).unwrap(), 0);

    // same figure schema as a simulated run, plus the crossval reports
    let timeline =
        std::fs::read_to_string(out.join("fig_timeline.csv")).unwrap();
    assert!(timeline
        .starts_with("time_s,load,load_ma,throughput,throughput_ma,rt_mean_s,rt_ma_s\n"));
    assert!(out.join("fig_per_client.csv").exists());
    assert!(out.join("fig_availability.csv").exists());
    let cv = std::fs::read_to_string(out.join("crossval.csv")).unwrap();
    assert!(cv.starts_with("metric,sim,live,rel_diff\n"), "{cv}");
    assert!(out.join("crossval_curve.csv").exists());
    let summary = std::fs::read_to_string(out.join("summary.txt")).unwrap();
    assert!(summary.contains("agent throughput"), "{summary}");
    assert!(summary.contains("crossval"), "{summary}");
    let json = std::fs::read_to_string(&bench).unwrap();
    assert!(json.contains("agent_throughput"), "{json}");
    assert!(json.contains("\"queue\":\"live\""), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

/// §3 disconnect semantics, live: the agent stops issuing clients the
/// moment its controller session is torn down, orders of magnitude
/// before its 60 s test duration would end.
#[test]
fn agent_stops_the_moment_its_session_drops() {
    let ts = TimeServer::spawn(LiveClock::ideal()).unwrap();
    let target = Target::spawn(
        &TargetKind::Ps(PsTargetParams {
            demand_s: 0.002,
            spread: 1.0 + 1e-9,
            speed: 1.0,
        }),
        3,
    )
    .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let ctrl_addr = listener.local_addr().unwrap();
    let p = AgentParams {
        id: 0,
        ctrl_addr,
        ts_addr: ts.addr,
        call: CallMode::Framed(target.addr),
        clock: LiveClock::ideal(),
    };
    let agent = std::thread::spawn(move || run_agent(p));

    // controller side of the handshake, by hand
    let (mut sess, _) = listener.accept().unwrap();
    for _ in 0..2 {
        let frame = wire::read_frame(&mut sess).unwrap();
        match wire::decode_up(&frame).unwrap() {
            WireUp::Hello { agent } => assert_eq!(agent, 0),
            WireUp::DeployDone => {}
            other => panic!("unexpected handshake frame {other:?}"),
        }
    }
    let desc = TestDescription {
        duration_s: 60.0,
        client_interval_s: 0.01,
        sync_interval_s: 0.2,
        rate_cap_per_s: f64::INFINITY,
        timeout_s: 5.0,
        give_up_failures: 0,
    };
    wire::write_frame(&mut sess, &wire::encode_ctrl(&CtrlMsg::Start(desc)))
        .unwrap();

    // let it test for a moment, then kill the session without a Stop
    std::thread::sleep(Duration::from_millis(500));
    sess.shutdown(Shutdown::Both).unwrap();
    let t0 = Instant::now();
    let rep = agent.join().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert!(dt < 10.0, "agent took {dt}s to notice the dead session");
    assert!(rep.session_dropped, "drop must be reported: {rep:?}");
    assert!(!rep.finished);
    assert!(rep.calls > 0, "the agent should have been testing");
}

/// Controller-side teardown: consecutive-failure eviction closes the
/// session, which stops the agent — the whole run winds down long
/// before the configured duration.
#[test]
fn eviction_drops_sessions_and_ends_the_run_early() {
    // a port with nothing behind it: every probe is ConnectionRefused
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let mut cfg = live::live_smoke(13);
    cfg.agents = 2;
    cfg.controller.stagger_s = 0.05;
    cfg.controller.desc.duration_s = 30.0;
    cfg.controller.desc.client_interval_s = 0.05;
    cfg.controller.desc.sync_interval_s = 0.3;
    cfg.controller.eviction_failures = 2;
    cfg.grace_s = 0.5;
    cfg.target = TargetSel::External(dead_addr.to_string());
    let t0 = Instant::now();
    let r = live::run_live(&cfg).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert!(dt < 25.0, "eviction should end the run early, took {dt}s");
    assert!(
        r.data.testers.iter().all(|t| t.evicted),
        "every failing agent must be evicted: {:?}",
        r.data
            .testers
            .iter()
            .map(|t| (t.id, t.evicted))
            .collect::<Vec<_>>()
    );
    assert!(r.samples() > 0, "the failing samples still get aggregated");
    assert_eq!(r.stream.binned.total_ok, 0.0, "nothing can have succeeded");
    // no sim twin exists for an external target
    assert!(crossval::compare(&cfg, &r).unwrap().is_none());
}
