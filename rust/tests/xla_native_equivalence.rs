//! The cross-layer contract: the AOT-compiled XLA analysis (L1 Pallas
//! kernels + L2 JAX graph, built by `make artifacts`) must agree with
//! the native rust analysis on real experiment data.
//!
//! Skip-with-reason policy (triaged): every test here funnels through
//! the `xla()` helper, which returns `None` — printing a loud `SKIP:`
//! line — whenever the AOT artifacts cannot be loaded. That covers two
//! legitimate situations, neither of which is a product bug:
//!
//! 1. `artifacts/` has not been built (no JAX toolchain on the box);
//!    `make artifacts` produces it where Python+JAX are available.
//! 2. The build uses the vendored `xla` stub crate, whose
//!    `PjRtClient::cpu()` intentionally errors at runtime. The native
//!    rust analysis is the authority there, and everything that
//!    consumes `XlaAnalyzer` already falls back to the native path.
//!
//! The equivalence asserts only run on hosts with real artifacts and a
//! real PJRT client; everywhere else these tests pass as explicit,
//! logged skips rather than failures.

use diperf::analysis::{self, AnalysisInput};
use diperf::experiment::{presets, run_experiment};
use diperf::experiments::{NUM_CLIENTS, NUM_QUANTA, WINDOW_S};
use diperf::runtime::XlaAnalyzer;

fn xla() -> Option<XlaAnalyzer> {
    match XlaAnalyzer::load("artifacts") {
        Ok(x) => Some(x),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e:#}); run `make artifacts`");
            None
        }
    }
}

fn max_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn agrees_on_http_run() {
    let Some(mut xla) = xla() else { return };
    let r = run_experiment(&presets::quick_http(6, 120.0, 3));
    let inp = AnalysisInput::from_run(&r.data, NUM_QUANTA, WINDOW_S);
    let x = xla.analyze(&inp).unwrap();
    let n = analysis::analyze(&inp, NUM_QUANTA, NUM_CLIENTS);
    assert!(max_diff(&x.tput, &n.tput) < 1e-3);
    assert!(max_diff(&x.load, &n.load) < 5e-2);
    assert!(max_diff(&x.rt_mean, &n.rt_mean) < 1e-3);
    assert!(max_diff(&x.rt_ma, &n.rt_ma) < 1e-3);
    assert!(max_diff(&x.completed, &n.completed) < 1e-3);
    assert!(max_diff(&x.util, &n.util) < 1e-3);
    assert!((x.totals[0] - n.totals[0]).abs() < 0.5);
}

#[test]
fn agrees_on_gram_run_with_failures() {
    let Some(mut xla) = xla() else { return };
    let mut cfg = presets::prews_small(12, 400.0, 9);
    cfg.testbed.failure_rate_per_hour = 1.0;
    let r = run_experiment(&cfg);
    let inp = AnalysisInput::from_run(&r.data, NUM_QUANTA, WINDOW_S);
    let x = xla.analyze(&inp).unwrap();
    let n = analysis::analyze(&inp, NUM_QUANTA, NUM_CLIENTS);
    assert!(max_diff(&x.tput, &n.tput) < 1e-3);
    assert!(max_diff(&x.load, &n.load) < 5e-2);
    // fairness/util involve divisions; allow a touch more slack for f32
    assert!(max_diff(&x.util, &n.util) < 1e-2);
    assert!(max_diff(&x.active_time, &n.active_time) < 0.5);
}

#[test]
fn variant_selection_picks_smallest_fit() {
    let Some(xla) = xla() else { return };
    let variants = xla.variants();
    assert!(variants.len() >= 3, "expected 3 capacity variants");
    assert!(variants.windows(2).all(|w| w[0].samples < w[1].samples));
    // boundary behaviour
    assert_eq!(xla.pick(0).unwrap(), 0);
    assert_eq!(xla.pick(variants[0].samples).unwrap(), 0);
    assert_eq!(xla.pick(variants[0].samples + 1).unwrap(), 1);
    assert!(xla.pick(variants.last().unwrap().samples + 1).is_err());
}

#[test]
fn polynomial_models_agree_in_value_space() {
    let Some(mut xla) = xla() else { return };
    let r = run_experiment(&presets::prews_small(10, 300.0, 4));
    let inp = AnalysisInput::from_run(&r.data, NUM_QUANTA, WINDOW_S);
    let x = xla.analyze(&inp).unwrap();
    let n = analysis::analyze(&inp, NUM_QUANTA, NUM_CLIENTS);
    // coefficients are ill-conditioned individually; compare evaluated
    // trends across the run instead
    let dur = inp.duration as f64;
    for frac in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let t = frac * dur;
        let xa = x.poly_rt_at(t, 0.0, dur);
        let na = n.poly_rt_at(t, 0.0, dur);
        assert!(
            (xa - na).abs() < 0.05 * (na.abs() + 1.0),
            "poly rt at {t}: xla {xa} vs native {na}"
        );
    }
}
