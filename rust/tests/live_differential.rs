//! Differential test: the thread-per-agent backend and the reactor
//! backend must be *interchangeable* — same seed, same load spec, same
//! protocol ⇒ statistically equivalent figure series.
//!
//! The comparison reuses the sim-vs-live crossval machinery
//! ([`diperf::live::crossval::build`]) on the two runs' binned
//! throughput series — the exact data behind `fig_timeline.csv` — and
//! holds the divergence under the same generous bound CI applies to
//! sim-vs-live smoke runs.  Both protocols are exercised: the framed
//! wire codec and real HTTP/1.1.
//!
//! De-flaking policy (see `live_harness.rs`): these tests' subject
//! matter *is* wall-clock behaviour over real loopback sockets, so they
//! are `#[ignore]`d by default and CI runs them explicitly with
//! `cargo test --test live_differential -- --ignored`.  Timing-derived
//! bounds re-run on violation; correctness properties fail fast.

// the reactor backend is epoll/poll-based
#![cfg(unix)]

use std::time::{Duration, Instant};

use diperf::live::{self, crossval, AgentBackend, LiveConfig, ProtocolKind};

/// Divergence ceiling between the two backends — the same generous
/// bound CI's live-smoke applies to sim-vs-live (`--crossval-bound`).
const DIFF_BOUND: f64 = 0.6;

/// Re-run a timing-sensitive scenario until it passes or `deadline` of
/// wall-clock time is spent; correctness violations panic inside the
/// closure and fail on the first attempt.
fn retry_with_deadline<F>(deadline: Duration, mut attempt: F)
where
    F: FnMut() -> Result<(), String>,
{
    let t0 = Instant::now();
    let mut tries = 0u32;
    loop {
        tries += 1;
        let err = match attempt() {
            Ok(()) => return,
            Err(e) => e,
        };
        if t0.elapsed() >= deadline {
            panic!("still failing after {tries} attempts over {deadline:?}: {err}");
        }
        eprintln!("[retry] attempt {tries} failed ({err}); retrying");
        std::thread::sleep(Duration::from_millis(200));
    }
}

/// The shared load spec: 64 agents over loopback, identical for both
/// backends down to the seed (skews and drifts derive identically, so
/// the runs are directly comparable).
fn spec(seed: u64, protocol: ProtocolKind, backend: AgentBackend) -> LiveConfig {
    let mut cfg = live::live_smoke(seed);
    cfg.agents = 64;
    cfg.backend = backend;
    cfg.workers = 2;
    cfg.protocol = protocol;
    cfg.controller.stagger_s = 0.01;
    cfg.controller.desc.duration_s = 2.0;
    cfg.controller.desc.client_interval_s = 0.05;
    cfg.controller.desc.sync_interval_s = 0.5;
    cfg.grace_s = 1.0;
    cfg
}

fn backends_agree(protocol: ProtocolKind) {
    retry_with_deadline(Duration::from_secs(240), || {
        let t = live::run_live(&spec(29, protocol, AgentBackend::Thread))
            .map_err(|e| format!("thread run: {e:#}"))?;
        let r = live::run_live(&spec(29, protocol, AgentBackend::Reactor))
            .map_err(|e| format!("reactor run: {e:#}"))?;

        // timing-derived gates first: a stalled runner re-runs
        if t.connected != 64 {
            return Err(format!("thread: {}/64 agents connected", t.connected));
        }
        if r.connected != 64 {
            return Err(format!("reactor: {}/64 agents connected", r.connected));
        }
        if t.samples() < 200 || r.samples() < 200 {
            return Err(format!(
                "thin runs: thread {} / reactor {} samples",
                t.samples(),
                r.samples()
            ));
        }
        if t.stream.binned.total_ok <= 0.0 || r.stream.binned.total_ok <= 0.0 {
            return Err("a backend saw no successful calls".into());
        }

        // the differential core: the two backends' figure series
        // through the crossval comparator
        let cv = crossval::build(&t.stream.binned, &r.stream.binned);
        if cv.divergence >= DIFF_BOUND {
            return Err(format!(
                "thread-vs-reactor divergence {:.3} >= {DIFF_BOUND} ({})",
                cv.divergence,
                protocol.label()
            ));
        }

        // exact correctness properties: fail fast, never retried
        assert_eq!(t.protocol_label, protocol.label());
        assert_eq!(r.protocol_label, protocol.label());
        assert_eq!(t.data.testers.len(), r.data.testers.len());
        let t_sent: u64 = t.agent_reports.iter().map(|a| a.samples_sent).sum();
        let r_sent: u64 = r.agent_reports.iter().map(|a| a.samples_sent).sum();
        assert_eq!(t_sent, t.samples(), "thread-backend sample conservation");
        assert_eq!(r_sent, r.samples(), "reactor-backend sample conservation");
        // both figure CSV surfaces carry the full schema
        let csv = crossval::csv(&cv);
        assert!(csv.starts_with("metric,sim,live,rel_diff\n"), "{csv}");
        assert_eq!(
            crossval::curve_csv(&cv).trim().lines().count(),
            1 + crossval::CURVE_POINTS
        );
        Ok(())
    });
}

#[test]
#[ignore = "wall-clock subject: 2×64 agents over real loopback sockets; CI runs it via -- --ignored"]
fn thread_and_reactor_backends_agree_under_the_wire_protocol() {
    backends_agree(ProtocolKind::Wire);
}

#[test]
#[ignore = "wall-clock subject: 2×64 agents over real loopback sockets; CI runs it via -- --ignored"]
fn thread_and_reactor_backends_agree_under_http11() {
    backends_agree(ProtocolKind::Http11);
}
