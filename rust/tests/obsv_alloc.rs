//! The disabled flight recorder's zero-allocation contract, proven with
//! a counting global allocator: a hundred thousand `count!`/`span!`
//! call sites with the recorder off must not allocate a single time.
//! This is its own test binary because `#[global_allocator]` is
//! process-wide — counting every allocation in the main suite would be
//! noise, and nothing here may enable the recorder.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

// SAFETY: delegates every operation to `System`, only adding a relaxed
// counter bump on the allocating paths.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn disabled_recorder_allocates_nothing() {
    assert!(
        !diperf::obsv::enabled(),
        "recorder must start disabled in this binary"
    );
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    let mut acc = 0u64;
    for i in 0..100_000u64 {
        let i = std::hint::black_box(i);
        diperf::obsv::count!(diperf::obsv::Kind::SimEvents, i);
        diperf::obsv::count!(diperf::obsv::Kind::ReactorEagain, 1);
        let g = diperf::obsv::span!(diperf::obsv::Kind::SimRun, i);
        acc = acc.wrapping_add(i);
        drop(g);
        let g2 = diperf::obsv::span!(diperf::obsv::Kind::ShardWindow);
        std::hint::black_box(&g2);
    }
    std::hint::black_box(acc);
    let after = ALLOC_CALLS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "disabled recorder allocated {} times across 100k call sites",
        after - before
    );
    // and it recorded nothing either — the counters never moved
    assert_eq!(diperf::obsv::counter(diperf::obsv::Kind::SimEvents), 0);
    assert_eq!(diperf::obsv::counter(diperf::obsv::Kind::ReactorEagain), 0);
}
