//! The replay regression corpus: golden FNV-1a 64 digests of the full
//! rendered report set (timeline, per-client, availability, churn
//! summary) for a fixed family of seeded experiments, on both the
//! single-engine and the sharded runner.
//!
//! The digests live in `tests/fixtures/replay_corpus/digests.txt`.
//! Three modes, driven by environment variables:
//!
//! - default: entries with a recorded digest must reproduce it bit for
//!   bit; entries without one fall back to an in-process determinism
//!   self-check (run twice, digests must agree) so a fresh checkout
//!   still passes before anyone has blessed a corpus;
//! - `DIPERF_BLESS=1`: recompute every digest and (re)write the fixture
//!   file — the update workflow after an *intentional* behavior change;
//! - `DIPERF_REQUIRE_CORPUS=1`: a missing digest is a failure — CI sets
//!   this after blessing to prove the file round-trips.
//!
//! See `tests/fixtures/replay_corpus/README.md` for the workflow.

use std::collections::BTreeMap;
use std::path::PathBuf;

use diperf::analysis;
use diperf::experiment::{
    presets, run_experiment_opts, ExperimentConfig, RunOptions,
};
use diperf::metrics::CollectionMode;
use diperf::report;

/// FNV-1a 64 — tiny, dependency-free, and stable across platforms.
fn fnv1a64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325_u64;
    for &b in s.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// The corpus: name, experiment, shard count (`None` = single engine).
/// Names are part of the fixture format — renaming one orphans its
/// recorded digest.
fn corpus() -> Vec<(&'static str, ExperimentConfig, Option<usize>)> {
    vec![
        ("churn-10x80-seed404", presets::churn_study(10, 80.0, 404), None),
        ("spike-10x80-seed405", presets::spike_study(10, 80.0, 405), None),
        ("soak-8x80-seed406", presets::soak(8, 80.0, 406), None),
        (
            "churn-10x80-seed404-shard4",
            presets::churn_study(10, 80.0, 404),
            Some(4),
        ),
    ]
}

/// Run one corpus entry and digest its rendered report set.
fn run_digest(cfg: &ExperimentConfig, shards: Option<usize>) -> String {
    let r = run_experiment_opts(
        cfg,
        RunOptions {
            shards,
            collect: CollectionMode::Stream,
            ..RunOptions::default()
        },
    );
    let agg = r.stream.as_ref().expect("streaming aggregator");
    let out = analysis::output_from_binned(&agg.binned);
    let churn = analysis::churn_from_stream(agg, &r.data.testers);
    let blob = format!(
        "timeline\n{}per_client\n{}churn\n{}summary\n{}",
        report::timeline_csv(&out, r.grid.t0, r.grid.quantum),
        report::per_client_csv(&out, &r.data),
        report::churn_csv(&churn, r.grid.t0, r.grid.quantum),
        report::churn_summary(&churn),
    );
    format!("{:016x}", fnv1a64(&blob))
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/replay_corpus/digests.txt")
}

fn read_digests(path: &PathBuf) -> BTreeMap<String, String> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return BTreeMap::new();
    };
    text.lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let mut it = l.split_whitespace();
            Some((it.next()?.to_string(), it.next()?.to_string()))
        })
        .collect()
}

#[test]
fn replay_corpus_digests_are_stable() {
    let bless = std::env::var("DIPERF_BLESS").as_deref() == Ok("1");
    let require = std::env::var("DIPERF_REQUIRE_CORPUS").as_deref() == Ok("1");
    let path = fixture_path();
    let recorded = read_digests(&path);
    let mut fresh: Vec<(String, String)> = Vec::new();
    for (name, cfg, shards) in corpus() {
        let got = run_digest(&cfg, shards);
        match recorded.get(name) {
            Some(want) if !bless => {
                assert_eq!(
                    &got, want,
                    "{name}: replay digest drifted from the recorded corpus. \
                     If this change is intentional, re-bless with \
                     `DIPERF_BLESS=1 cargo test --test replay_corpus` \
                     (see tests/fixtures/replay_corpus/README.md)."
                );
            }
            _ => {
                assert!(
                    bless || !require,
                    "{name}: no recorded digest but DIPERF_REQUIRE_CORPUS=1"
                );
                // no golden value yet: the entry still must replay
                // deterministically within this process
                let again = run_digest(&cfg, shards);
                assert_eq!(got, again, "{name}: nondeterministic replay");
            }
        }
        fresh.push((name.to_string(), got));
    }
    if bless {
        let mut text = String::from(
            "# Golden replay digests (FNV-1a 64 of the rendered report set).\n\
             # Regenerate with: DIPERF_BLESS=1 cargo test --test replay_corpus\n",
        );
        for (name, d) in &fresh {
            text.push_str(&format!("{name} {d}\n"));
        }
        std::fs::create_dir_all(path.parent().expect("fixture dir"))
            .expect("creating fixture dir");
        std::fs::write(&path, text).expect("writing blessed digests");
        eprintln!("[replay_corpus] blessed {} digests -> {}", fresh.len(), path.display());
    }
}
