//! Integration tests: full miniature DiPerF experiments across the
//! controller/tester/service/network stack, checking the paper's system
//! properties end to end.

use diperf::analysis::{self, AnalysisInput};
use diperf::experiment::{presets, run_experiment, ServiceKind};
use diperf::experiments::{self, run_with_analysis};
use diperf::metrics::SampleOutcome;
use diperf::services::gram_ws::GramWsParams;

#[test]
fn prews_ramp_shape_holds_at_small_scale() {
    // 20 testers, 10 s stagger, 10 min each — the E1 shape in miniature
    let cfg = presets::prews_small(20, 600.0, 11);
    let run = run_with_analysis(&cfg);
    let out = &run.out;

    // load ramps to ~20 and back down
    let peak = out.load.iter().cloned().fold(0.0, f64::max);
    assert!((18.0..=21.0).contains(&peak), "peak load {peak}");

    // rt grows with load: heavy-load rt must exceed light-load rt
    let rt_l = experiments::rt_light_load(&run);
    let rt_h = experiments::rt_heavy_load(&run);
    assert!(rt_h > rt_l * 1.5, "rt did not grow: {rt_l} -> {rt_h}");

    // per-job service cost stays ~constant (the paper's CPU-bound
    // signature): completions * demand ~ busy time
    assert!(run.result.data.completed() > 500);
}

#[test]
fn conservation_across_the_stack() {
    let cfg = presets::prews_small(10, 300.0, 3);
    let r = run_experiment(&cfg);
    let st = r.service_stats;
    // every service-side request is accounted
    assert!(st.submitted >= st.completed + st.denied + st.errored);
    // every tester sample is classified
    let d = &r.data;
    let by_class = |o: SampleOutcome| {
        d.samples.iter().filter(|s| s.outcome == o).count()
    };
    let total = by_class(SampleOutcome::Success)
        + by_class(SampleOutcome::Timeout)
        + by_class(SampleOutcome::StartFailure)
        + by_class(SampleOutcome::Denied)
        + by_class(SampleOutcome::ServiceError);
    assert_eq!(total, d.samples.len());
    // tester-side successes cannot exceed service-side completions
    assert!(by_class(SampleOutcome::Success) as u64 <= st.completed);
}

#[test]
fn clock_reconciliation_beats_raw_skew() {
    // WAN testbed with pathological clocks: reconciled times must land
    // within tens of ms of truth even when raw skew is in the thousands
    // of seconds
    let mut cfg = presets::prews_small(12, 240.0, 5);
    cfg.testbed.clock_good = 0.3;
    cfg.testbed.clock_moderate = 0.3; // 40% of nodes get wild clocks
    let r = run_experiment(&cfg);
    let mut errs: Vec<f64> = r
        .data
        .samples
        .iter()
        .filter(|s| s.t_end_true.is_finite())
        .map(|s| (s.t_end - s.t_end_true).abs())
        .collect();
    assert!(errs.len() > 100);
    errs.sort_by(f64::total_cmp);
    let median = errs[errs.len() / 2];
    let p99 = errs[errs.len() * 99 / 100];
    assert!(median < 0.15, "median reconciliation error {median}");
    assert!(p99 < 1.0, "p99 reconciliation error {p99}");
}

#[test]
fn node_failures_are_detected_and_evicted() {
    let mut cfg = presets::prews_small(12, 900.0, 9);
    cfg.testbed.failure_rate_per_hour = 3.0; // very flaky testbed
    cfg.controller.silence_timeout_s = 120.0;
    let r = run_experiment(&cfg);
    let evicted = r.data.testers.iter().filter(|t| t.evicted).count();
    assert!(
        evicted >= 2,
        "flaky nodes should be evicted by the silence detector \
         ({evicted} evicted)"
    );
    // evicted testers stop contributing samples after eviction
    for t in r.data.testers.iter().filter(|t| t.evicted) {
        let after: usize = r
            .data
            .samples
            .iter()
            .filter(|s| s.tester == t.id && s.t_end > t.stopped_at + 60.0)
            .count();
        assert_eq!(after, 0, "tester {} reported after eviction", t.id);
    }
}

#[test]
fn ws_overload_fails_ungracefully_and_small_run_recovers() {
    // small-scale §4.2: 14 testers vs a WS GRAM scaled to capacity ~8
    let mut cfg = presets::ws_fig6(3);
    cfg.testbed.num_testers = 14;
    cfg.service = ServiceKind::GramWs(GramWsParams {
        job_demand_s: 3.0,
        stall_threshold: 8,
        resume_threshold: 6,
        hard_client_limit: 20,
        ..Default::default()
    });
    cfg.controller.desc.duration_s = 1500.0;
    let r = run_experiment(&cfg);
    let evicted = r.data.testers.iter().filter(|t| t.evicted).count();
    assert!(evicted >= 1, "shedding should evict someone");
    assert!(
        r.data.completed() > 50,
        "service must keep serving after shedding ({} ok)",
        r.data.completed()
    );
}

#[test]
fn rate_cap_is_respected() {
    // §4.3 style: per-client rate cap of 2/s on a fast service
    let mut cfg = presets::quick_http(4, 120.0, 13);
    cfg.controller.desc.rate_cap_per_s = 2.0;
    cfg.controller.desc.client_interval_s = 0.0;
    let r = run_experiment(&cfg);
    for t in &r.data.testers {
        let mine: Vec<f64> = r
            .data
            .samples
            .iter()
            .filter(|s| s.tester == t.id)
            .map(|s| s.t_start)
            .collect();
        let span = t.stopped_at - t.started_at;
        let rate = mine.len() as f64 / span.max(1.0);
        assert!(
            rate < 2.3,
            "tester {} exceeded the 2/s cap: {rate:.2}/s",
            t.id
        );
    }
}

#[test]
fn analysis_input_roundtrip_from_experiment() {
    let cfg = presets::quick_http(5, 90.0, 17);
    let r = run_experiment(&cfg);
    let inp = AnalysisInput::from_run(&r.data, 128, 20.0);
    let out = analysis::analyze(&inp, 128, 16);
    // binned completions == sample-level completions (all within range)
    let binned: f64 = out.tput.iter().sum();
    assert_eq!(binned as usize, r.data.completed());
    // offered-load integral == sum of in-flight spans
    let span_sum: f64 = r
        .data
        .samples
        .iter()
        .map(|s| (s.t_end - s.t_start).max(0.0))
        .sum();
    assert!(
        (out.totals[6] - span_sum).abs() / span_sum < 0.02,
        "load integral {} vs span sum {span_sum}",
        out.totals[6]
    );
}

#[test]
fn deterministic_replay_full_stack() {
    let cfg = presets::prews_small(8, 240.0, 21);
    let a = run_experiment(&cfg);
    let b = run_experiment(&cfg);
    assert_eq!(a.events, b.events);
    assert_eq!(a.data.samples.len(), b.data.samples.len());
    for (x, y) in a.data.samples.iter().zip(&b.data.samples) {
        assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        assert_eq!(x.rt.to_bits(), y.rt.to_bits());
        assert_eq!(x.outcome, y.outcome);
    }
}

#[test]
fn seeds_change_outcomes() {
    let a = run_experiment(&presets::prews_small(8, 240.0, 1));
    let b = run_experiment(&presets::prews_small(8, 240.0, 2));
    assert_ne!(
        a.data.samples.len(),
        b.data.samples.len(),
        "different seeds should produce different sample counts"
    );
}
