//! Deterministic tests for the live reactor (`live::reactor`).
//!
//! Every test drives the *real* agent state machine — the same code
//! that runs under epoll in production — through the in-memory
//! `EventSource`/`Clock` doubles.  No real sockets, no sleeps: time
//! advances only when a test says so, readiness is scripted, and the
//! whole run is bit-stable across executions.  The scenarios cover the
//! corners a readiness loop must survive: 1-byte dribble reads and
//! writes, EAGAIN storms (spurious wakeups), mid-frame disconnects,
//! dead targets, tester timeouts, and time-server outages.

use std::io::ErrorKind;

use diperf::live::reactor::testing::{MockClock, MockNet};
use diperf::live::reactor::{AgentSpec, Endpoint, TargetMode, Worker};
use diperf::live::target::OUT_OK;
use diperf::live::wire::{self, FrameBuf, WireUp};
use diperf::metrics::SampleOutcome;
use diperf::transport::{CtrlMsg, GoodbyeReason, TestDescription};

/// One worker over the mock fabric plus the handles to script it.
struct Rig {
    net: MockNet,
    clock: MockClock,
    w: Worker<MockNet, MockClock>,
}

impl Rig {
    fn new(agents: u32, mode: TargetMode) -> Rig {
        let specs: Vec<AgentSpec> = (0..agents)
            .map(|id| AgentSpec {
                id,
                skew_s: 0.0,
                drift: 0.0,
            })
            .collect();
        Rig::with_specs(&specs, mode)
    }

    fn with_specs(specs: &[AgentSpec], mode: TargetMode) -> Rig {
        let net = MockNet::new();
        let clock = MockClock::new();
        let w = Worker::new(net.clone(), clock.clone(), specs, mode);
        Rig { net, clock, w }
    }

    /// Advance time and run one event-loop turn.
    fn step(&mut self, dt: f64) {
        self.clock.advance(dt);
        self.w.tick(None).expect("mock wait never fails");
    }

    /// Step in small increments until the worker is done (bounded, so
    /// a livelock fails the test instead of hanging it).
    fn settle(&mut self) {
        for _ in 0..1000 {
            if self.w.all_done() {
                return;
            }
            self.step(0.001);
        }
        panic!("worker did not finish within 1000 steps");
    }

    fn ctrl(&self, i: usize) -> u64 {
        self.net.tokens(Endpoint::Ctrl)[i]
    }

    fn ts(&self) -> u64 {
        let toks = self.net.tokens(Endpoint::TimeServer);
        *toks.last().expect("ts link exists")
    }
}

/// A controller frame as it appears on the wire.
fn ctrl_frame(msg: &CtrlMsg) -> Vec<u8> {
    let p = wire::encode_ctrl(msg);
    let mut out = (p.len() as u32).to_be_bytes().to_vec();
    out.extend_from_slice(&p);
    out
}

/// A time-server stamp as it appears on the wire.
fn stamp(server_s: f64) -> [u8; 8] {
    server_s.to_bits().to_be_bytes()
}

fn decode_frames(bytes: &[u8]) -> Vec<WireUp> {
    let mut fb = FrameBuf::new();
    fb.push(bytes);
    let mut out = Vec::new();
    while let Some(p) = fb.pop().expect("well-formed frames") {
        out.push(wire::decode_up(&p).expect("decodable frame"));
    }
    assert_eq!(fb.pending(), 0, "trailing partial frame");
    out
}

fn desc(duration_s: f64, give_up: u32) -> TestDescription {
    TestDescription {
        duration_s,
        client_interval_s: 0.0,
        sync_interval_s: 1.0,
        rate_cap_per_s: f64::INFINITY,
        timeout_s: 5.0,
        give_up_failures: give_up,
    }
}

/// Drive a fresh single-agent rig through handshake → Start → probe →
/// first sync, leaving it Running with a launch armed.  Returns the
/// (ctrl, target) tokens.
fn to_running(rig: &mut Rig, d: TestDescription) -> (u64, u64) {
    rig.step(0.001); // connects resolve, Hello + DeployDone drain
    let ctrl = rig.ctrl(0);
    let hs = decode_frames(&rig.net.take_outbound(ctrl));
    assert!(matches!(hs[0], WireUp::Hello { agent: 0 }), "{hs:?}");
    assert!(matches!(hs[1], WireUp::DeployDone), "{hs:?}");

    rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Start(d)));
    rig.step(0.001); // Start read; latency probe begins
    let tgt = *rig.net.tokens(Endpoint::Target).last().unwrap();
    rig.step(0.001); // probe connect resolves; sync requested
    assert_eq!(rig.net.take_outbound(rig.ts()), vec![1u8]);
    rig.net.deliver(rig.ts(), &stamp(1000.0));
    rig.step(0.001); // sync completes; first launch armed
    let frames = decode_frames(&rig.net.take_outbound(ctrl));
    assert!(
        frames.iter().any(|f| matches!(f, WireUp::Sync(_))),
        "expected a Sync frame, got {frames:?}"
    );
    (ctrl, tgt)
}

/// Collect every sample across all Samples frames.
fn all_samples(frames: &[WireUp]) -> Vec<diperf::metrics::CallSample> {
    frames
        .iter()
        .filter_map(|f| match f {
            WireUp::Samples(v) => Some(v.clone()),
            _ => None,
        })
        .flatten()
        .collect()
}

#[test]
fn full_lifecycle_success_timeout_and_goodbye() {
    let mut rig = Rig::new(1, TargetMode::Framed);
    let (ctrl, tgt) = to_running(&mut rig, desc(10.0, 0));

    rig.step(0.001); // launch #1 fires
    assert_eq!(rig.net.take_outbound(tgt), vec![1u8]);
    for _ in 0..3 {
        rig.net.deliver(tgt, &[OUT_OK]);
        rig.step(0.001); // reply → sample; next launch armed
        rig.step(0.001); // next launch fires
        assert_eq!(rig.net.take_outbound(tgt), vec![1u8]);
    }
    // 4 launches, 3 replies; the 4th call never answers.  Jump past
    // the call timeout and the test duration in one go: the timer
    // wheel replays the deadlines in order (timeout, then duration).
    rig.clock.advance(11.0);
    rig.w.tick(None).unwrap();
    rig.settle();

    let frames = decode_frames(&rig.net.take_outbound(ctrl));
    let samples = all_samples(&frames);
    assert_eq!(samples.len(), 4);
    let ok = samples
        .iter()
        .filter(|s| s.outcome == SampleOutcome::Success)
        .count();
    let timed_out = samples
        .iter()
        .filter(|s| s.outcome == SampleOutcome::Timeout)
        .count();
    assert_eq!((ok, timed_out), (3, 1), "{samples:?}");
    // samples are in launch order with sane local timestamps
    for w in samples.windows(2) {
        assert!(w[0].seq < w[1].seq);
        assert!(w[0].t_submit_local <= w[1].t_submit_local);
    }
    assert!(
        matches!(frames.last(), Some(WireUp::Goodbye(GoodbyeReason::Finished))),
        "{frames:?}"
    );

    let rep = rig.w.reports()[0];
    assert_eq!(rep.calls, 4);
    assert_eq!(rep.samples_sent, 4);
    assert!(rep.syncs >= 1);
    assert!(rep.finished);
    assert!(!rep.session_dropped);
    assert!(!rig.net.is_open(ctrl), "agent must close after Goodbye");
}

#[test]
fn identical_runs_are_bit_stable() {
    let run = || {
        let mut rig = Rig::new(1, TargetMode::Framed);
        let (ctrl, tgt) = to_running(&mut rig, desc(3.0, 0));
        rig.step(0.001);
        let mut tgt_bytes = rig.net.take_outbound(tgt);
        for _ in 0..2 {
            rig.net.deliver(tgt, &[OUT_OK]);
            rig.step(0.001);
            rig.step(0.001);
            tgt_bytes.extend(rig.net.take_outbound(tgt));
        }
        rig.clock.advance(4.0);
        rig.w.tick(None).unwrap();
        rig.settle();
        let ctrl_bytes = rig.net.take_outbound(ctrl);
        (ctrl_bytes, tgt_bytes, format!("{:?}", rig.w.reports()))
    };
    let a = run();
    let b = run();
    assert_eq!(a.0, b.0, "controller byte stream must be bit-stable");
    assert_eq!(a.1, b.1, "target byte stream must be bit-stable");
    assert_eq!(a.2, b.2, "reports must be bit-stable");
}

#[test]
fn one_byte_dribble_reads_and_writes_still_work() {
    let mut rig = Rig::new(1, TargetMode::Framed);
    let ctrl = rig.ctrl(0);
    // every ctrl read and write moves one byte at a time
    rig.net.set_max_read(ctrl, 1);
    rig.net.set_max_write(ctrl, 1);
    rig.step(0.001);
    let hs = decode_frames(&rig.net.take_outbound(ctrl));
    assert_eq!(hs.len(), 2, "handshake survives 1-byte writes: {hs:?}");

    // deliver Start split into single bytes across separate ticks so
    // the frame assembles incrementally over many partial reads
    let frame = ctrl_frame(&CtrlMsg::Start(desc(5.0, 0)));
    for b in &frame {
        rig.net.deliver(ctrl, &[*b]);
        rig.step(0.001);
    }
    assert_eq!(
        rig.net.tokens(Endpoint::Target).len(),
        1,
        "Start must eventually parse and open the latency probe"
    );
}

#[test]
fn eagain_storms_are_survived() {
    let mut rig = Rig::new(1, TargetMode::Framed);
    let ctrl = rig.ctrl(0);
    rig.net.storm_writes(ctrl, 4); // handshake pump hits WouldBlock
    rig.step(0.001);
    rig.step(0.001);
    rig.step(0.001);
    rig.step(0.001);
    rig.step(0.001);
    let hs = decode_frames(&rig.net.take_outbound(ctrl));
    assert_eq!(hs.len(), 2, "handshake flushed after the storm: {hs:?}");

    rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Start(desc(5.0, 0))));
    rig.net.storm_reads(ctrl, 4); // readable wakeups that yield EAGAIN
    for _ in 0..6 {
        rig.step(0.001);
    }
    assert_eq!(
        rig.net.tokens(Endpoint::Target).len(),
        1,
        "Start processed once the read storm passes"
    );
}

#[test]
fn mid_frame_disconnect_drops_the_session() {
    let mut rig = Rig::new(1, TargetMode::Framed);
    let ctrl = rig.ctrl(0);
    rig.step(0.001);
    rig.net.take_outbound(ctrl);

    // half a Start frame, then the controller dies mid-frame
    let frame = ctrl_frame(&CtrlMsg::Start(desc(5.0, 0)));
    rig.net.deliver(ctrl, &frame[..frame.len() / 2]);
    rig.step(0.001);
    rig.net.close_peer(ctrl);
    rig.step(0.001);

    assert!(rig.w.all_done());
    let rep = rig.w.reports()[0];
    assert!(rep.session_dropped);
    assert!(!rep.finished);
    assert_eq!(rep.calls, 0, "never started, never launched");
}

#[test]
fn dead_target_gives_up_after_k_failures() {
    let mut rig = Rig::new(1, TargetMode::Framed);
    let (ctrl, tgt) = to_running(&mut rig, desc(30.0, 2));

    rig.step(0.001); // launch #1 writes its request
    assert_eq!(rig.net.take_outbound(tgt), vec![1u8]);
    rig.net.close_peer(tgt); // target dies mid-call
    rig.step(0.001); // EOF → ServiceError; relaunch armed
    rig.step(0.001); // launch #2 opens a fresh target connection
    let tgt2 = *rig.net.tokens(Endpoint::Target).last().unwrap();
    assert_ne!(tgt, tgt2);
    rig.step(0.001); // connect resolves, request written
    assert_eq!(rig.net.take_outbound(tgt2), vec![1u8]);
    rig.net.close_peer(tgt2);
    rig.step(0.001); // second ServiceError → give-up
    rig.settle();

    let frames = decode_frames(&rig.net.take_outbound(ctrl));
    let samples = all_samples(&frames);
    assert_eq!(samples.len(), 2);
    assert!(samples.iter().all(|s| s.outcome == SampleOutcome::ServiceError));
    assert!(
        matches!(
            frames.last(),
            Some(WireUp::Goodbye(GoodbyeReason::TooManyFailures))
        ),
        "{frames:?}"
    );
    let rep = rig.w.reports()[0];
    assert!(!rep.finished, "TooManyFailures is not Finished");
    assert!(!rep.session_dropped);
}

#[test]
fn stop_mid_run_flushes_and_drains_without_goodbye() {
    let mut rig = Rig::new(1, TargetMode::Framed);
    let (ctrl, tgt) = to_running(&mut rig, desc(30.0, 0));

    rig.step(0.001); // launch #1
    rig.net.take_outbound(tgt);
    rig.net.deliver(tgt, &[OUT_OK]);
    rig.step(0.001); // one sample buffered

    rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Stop));
    rig.step(0.001);
    rig.settle();

    let frames = decode_frames(&rig.net.take_outbound(ctrl));
    let samples = all_samples(&frames);
    assert_eq!(samples.len(), 1, "buffered sample flushed on Stop");
    assert!(
        !frames.iter().any(|f| matches!(f, WireUp::Goodbye(_))),
        "a Stopped agent does not say Goodbye: {frames:?}"
    );
    let rep = rig.w.reports()[0];
    assert!(!rep.finished);
    assert!(!rep.session_dropped, "Stop is orderly, not a drop");
    assert!(!rig.net.is_open(ctrl));
}

#[test]
fn time_server_outage_heartbeats_then_recovers() {
    let mut rig = Rig::new(1, TargetMode::Framed);
    let (ctrl, _tgt) = to_running(&mut rig, desc(30.0, 0));
    let ts1 = rig.ts();

    // kill the time-server link and make the immediate reconnect fail
    rig.net.refuse_next_connect(Endpoint::TimeServer, ErrorKind::ConnectionRefused);
    rig.net.close_peer(ts1);
    rig.step(0.001); // EOF on ts; reconnect refused → link down
    rig.net.take_outbound(ctrl);

    rig.clock.advance(1.1); // next sync interval
    rig.w.tick(None).unwrap();
    let frames = decode_frames(&rig.net.take_outbound(ctrl));
    assert!(
        frames.iter().any(|f| matches!(f, WireUp::Heartbeat)),
        "a sync round without a time server heartbeats: {frames:?}"
    );

    // the retry reopened the link; the next round syncs normally
    let ts2 = rig.ts();
    assert_ne!(ts1, ts2);
    rig.clock.advance(1.1);
    rig.w.tick(None).unwrap();
    rig.step(0.001);
    assert_eq!(rig.net.take_outbound(ts2), vec![1u8]);
    rig.net.deliver(ts2, &stamp(2000.0));
    rig.step(0.001);
    let frames = decode_frames(&rig.net.take_outbound(ctrl));
    assert!(
        frames.iter().any(|f| matches!(f, WireUp::Sync(_))),
        "sync resumes after the outage: {frames:?}"
    );
    assert_eq!(rig.w.reports()[0].syncs, 2);
}

#[test]
fn connect_probe_mode_counts_accepted_connections() {
    let mut rig = Rig::new(1, TargetMode::Probe);
    let (ctrl, _probe_conn) = to_running(&mut rig, desc(30.0, 0));

    rig.step(0.001); // launch #1: a fresh connect probe
    rig.step(0.001); // connect resolves → Success sample
    rig.step(0.001); // launch #2
    rig.step(0.001); // Success
    rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Stop));
    rig.step(0.001);
    rig.settle();

    let samples = all_samples(&decode_frames(&rig.net.take_outbound(ctrl)));
    assert!(samples.len() >= 2, "{samples:?}");
    assert!(samples.iter().all(|s| s.outcome == SampleOutcome::Success));
}

#[test]
fn skewed_agents_stamp_samples_in_local_time() {
    let specs = [AgentSpec {
        id: 0,
        skew_s: 250.0,
        drift: 50e-6,
    }];
    let mut rig = Rig::with_specs(&specs, TargetMode::Framed);
    let (ctrl, tgt) = to_running(&mut rig, desc(10.0, 0));
    rig.step(0.001);
    rig.net.take_outbound(tgt);
    rig.net.deliver(tgt, &[OUT_OK]);
    rig.step(0.001);
    rig.clock.advance(11.0);
    rig.w.tick(None).unwrap();
    rig.settle();

    let samples = all_samples(&decode_frames(&rig.net.take_outbound(ctrl)));
    assert!(!samples.is_empty());
    // local clock = mono * (1 + drift) + skew, so every stamp sits just
    // past the 250 s skew (mono time is a few milliseconds here)
    for s in &samples {
        assert!(
            s.t_submit_local > 250.0 && s.t_submit_local < 251.0,
            "sample not in the agent's local time: {s:?}"
        );
    }
    assert!(rig.w.reports()[0].finished);
}

#[test]
fn many_agents_share_one_worker_and_one_ts_link() {
    let mut rig = Rig::new(3, TargetMode::Framed);
    rig.step(0.001); // all handshakes drain
    let ts = rig.ts();
    for i in 0..3 {
        let ctrl = rig.ctrl(i);
        let hs = decode_frames(&rig.net.take_outbound(ctrl));
        assert!(
            matches!(hs[0], WireUp::Hello { agent } if agent == i as u32),
            "agent {i}: {hs:?}"
        );
        rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Start(desc(5.0, 0))));
    }
    rig.step(0.001); // Starts read; probes begin
    rig.step(0.001); // probes resolve; syncs queue FIFO on one link

    // the shared link serializes: one request byte at a time
    for k in 0..3 {
        assert_eq!(rig.net.take_outbound(ts), vec![1u8], "sync {k}");
        rig.net.deliver(ts, &stamp(1000.0 + k as f64));
        rig.step(0.001);
    }

    // let every agent run a couple of calls, then finish by duration
    for _ in 0..6 {
        for t in rig.net.tokens(Endpoint::Target) {
            if rig.net.is_open(t) && !rig.net.take_outbound(t).is_empty() {
                rig.net.deliver(t, &[OUT_OK]);
            }
        }
        rig.step(0.001);
    }
    rig.clock.advance(6.0);
    rig.w.tick(None).unwrap();
    rig.settle();

    let reports = rig.w.reports();
    assert_eq!(reports.len(), 3);
    for (i, rep) in reports.iter().enumerate() {
        assert!(rep.finished, "agent {i}: {rep:?}");
        assert!(!rep.session_dropped, "agent {i}: {rep:?}");
        assert!(rep.syncs >= 1, "agent {i}: {rep:?}");
        let frames = decode_frames(&rig.net.take_outbound(rig.ctrl(i)));
        assert!(
            matches!(
                frames.last(),
                Some(WireUp::Goodbye(GoodbyeReason::Finished))
            ),
            "agent {i}: {frames:?}"
        );
    }
}

#[test]
fn backpressure_pauses_launches_until_drained() {
    let mut rig = Rig::new(1, TargetMode::Framed);
    let (ctrl, tgt) = to_running(&mut rig, desc(300.0, 0));

    // stop the controller from draining anything further, then let the
    // agent try to push enough Samples frames to cross the high
    // watermark (64 KiB ≈ 60 frames of 32 samples x 33 bytes + header)
    rig.net.storm_writes(ctrl, u32::MAX);
    rig.step(0.001); // launch #1 fires
    let mut calls = 0u64;
    for _ in 0..4000 {
        let wrote = rig.net.take_outbound(tgt);
        if wrote.is_empty() {
            break; // paused: the launch gate is shut
        }
        calls += 1;
        rig.net.deliver(tgt, &[OUT_OK]);
        rig.step(0.001); // reply → sample (flush every 32nd)
        rig.step(0.001); // next launch (or: paused, nothing happens)
    }
    let rep = rig.w.reports()[0];
    assert!(
        rep.calls < 3500,
        "agent must pause under backpressure, ran {} calls",
        rep.calls
    );
    assert!(calls > 32, "agent batched at least one full flush first");

    // controller drains again: the agent resumes launching
    rig.net.storm_writes(ctrl, 0);
    rig.step(0.001); // wait reports writable; buffer drains; unpause
    rig.step(0.001); // launch fires again
    rig.step(0.001);
    assert!(
        !rig.net.take_outbound(ctrl).is_empty(),
        "queued frames drain once the controller reads again"
    );
    assert!(
        rig.w.reports()[0].calls > rep.calls,
        "launching resumes after the drain"
    );
}
