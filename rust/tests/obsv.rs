//! End-to-end flight-recorder contract:
//!
//! 1. **Pure observer** — replay digests of the rendered report set are
//!    bit-identical with the recorder off and on, single-engine and
//!    sharded.  Tracing must never perturb a measurement.
//! 2. **It records** — the instrumented layers actually produce events
//!    and spans while enabled, the Chrome dump round-trips through
//!    `analyze trace`'s summarizer, and the utilization report names
//!    the hub and shard threads.
//!
//! Everything lives in ONE test function: the recorder is process-global
//! state, and the default parallel test runner would otherwise interleave
//! an enabled phase with a test that assumes the recorder is off.

use diperf::analysis;
use diperf::experiment::{presets, run_experiment_opts, RunOptions};
use diperf::metrics::CollectionMode;
use diperf::report;

/// FNV-1a 64 — same digest the replay corpus uses.
fn fnv1a64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325_u64;
    for &b in s.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Run the corpus churn experiment and digest its rendered reports.
fn run_digest(shards: Option<usize>) -> String {
    let cfg = presets::churn_study(10, 80.0, 404);
    let r = run_experiment_opts(
        &cfg,
        RunOptions {
            shards,
            collect: CollectionMode::Stream,
            ..RunOptions::default()
        },
    );
    let agg = r.stream.as_ref().expect("streaming aggregator");
    let out = analysis::output_from_binned(&agg.binned);
    let churn = analysis::churn_from_stream(agg, &r.data.testers);
    let blob = format!(
        "timeline\n{}per_client\n{}churn\n{}summary\n{}",
        report::timeline_csv(&out, r.grid.t0, r.grid.quantum),
        report::per_client_csv(&out, &r.data),
        report::churn_csv(&churn, r.grid.t0, r.grid.quantum),
        report::churn_summary(&churn),
    );
    format!("{:016x}", fnv1a64(&blob))
}

#[test]
fn recorder_is_a_pure_observer_and_actually_records() {
    use diperf::obsv::{self, Kind};

    // -- baseline digests, recorder off ------------------------------
    assert!(!obsv::enabled(), "recorder must start disabled");
    let single_off = run_digest(None);
    let sharded_off = run_digest(Some(4));

    // -- same runs, recorder on --------------------------------------
    obsv::enable();
    let single_on = run_digest(None);
    let sharded_on = run_digest(Some(4));
    assert_eq!(
        single_off, single_on,
        "tracing perturbed the single-engine replay digest"
    );
    assert_eq!(
        sharded_off, sharded_on,
        "tracing perturbed the sharded replay digest"
    );

    // -- it recorded something meaningful ----------------------------
    assert!(
        obsv::counter(Kind::SimEvents) > 1_000,
        "sim.events = {}",
        obsv::counter(Kind::SimEvents)
    );
    assert!(
        obsv::counter(Kind::ShardWindow) > 0,
        "no shard windows recorded"
    );
    assert!(
        obsv::counter(Kind::MergeStall) > 0,
        "no merge stalls recorded"
    );
    let line = obsv::stats_line();
    assert!(line.contains("sim.events="), "stats line: {line}");
    assert!(line.contains("shard.window="), "stats line: {line}");

    // -- the dump round-trips through the analyzer --------------------
    let dir = std::env::temp_dir().join(format!(
        "diperf_obsv_e2e_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("trace.json");
    obsv::chrome::write_chrome_trace(trace_path.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let t = analysis::trace::summarize(&text).expect("dump parses");
    assert!(!t.spans.is_empty(), "dump has no spans");
    let labels: Vec<&str> =
        t.labels.values().map(String::as_str).collect();
    assert!(
        labels.iter().any(|l| l.starts_with("shard-")),
        "no shard thread labels in {labels:?}"
    );
    assert!(
        labels.iter().any(|l| *l == "hub"),
        "no hub thread label in {labels:?}"
    );
    let util = analysis::trace::utilization_csv(&t);
    assert!(
        util.lines().any(|l| l.contains(",shard-")),
        "utilization csv has no shard rows:\n{util}"
    );
    let spans = analysis::trace::top_spans_csv(&t);
    assert!(
        spans.lines().any(|l| l.starts_with("shard.window,")),
        "top spans csv misses shard.window:\n{spans}"
    );
    let stalls = analysis::trace::merge_stall_hist_csv(&t);
    assert!(
        stalls.lines().count() >= 2,
        "merge-stall histogram is empty:\n{stalls}"
    );

    // -- a second enabled run after reset() starts clean --------------
    obsv::reset();
    assert_eq!(obsv::counter(Kind::SimEvents), 0, "reset left counters");
    let _ = run_digest(None);
    assert!(
        obsv::counter(Kind::SimEvents) > 0,
        "threads did not re-register after reset"
    );

    // -- teardown: leave the process as we found it -------------------
    obsv::disable();
    obsv::reset();
    let _ = std::fs::remove_dir_all(&dir);
}
