//! Property tests for the sharded world's conservative merge: a
//! miniature K-owner harness drives [`WindowPlan`] and
//! [`sort_cross_messages`] with seeded, arbitrary cross-shard message
//! schedules — including zero-slack messages whose latency is *exactly*
//! the lookahead bound, zero-lookahead plans (which clamp to one tick),
//! idle owners and shard-local fault events — and re-states the
//! invariants the coordinator in `experiment::shard` relies on:
//!
//! - every cross-owner message arrives at or after the end of the
//!   window in which it was sent (the conservative bound);
//! - per owner, cross deliveries happen in `(time, tester, emit)`
//!   order, exactly the canonical merge order;
//! - the window loop strictly advances and reaches quiescence — it
//!   never deadlocks, livelocks or drops messages, even when some
//!   owners have nothing to do from the first window to the last.

use diperf::experiment::shard::{sort_cross_messages, WindowPlan};
use diperf::sim::{Engine, QueueKind, SimDuration, SimTime};
use diperf::util::proptest::{forall, prop};

#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Owner-local work (the stand-in for tester launches and sweeps).
    Local,
    /// A shard-local fault event (scenario Crash/Restart analogue).
    Fault,
    /// A cross-owner delivery carrying its canonical merge key.
    Cross { tester: usize, emit: u64 },
}

#[test]
fn arbitrary_schedules_merge_in_order_without_deadlock() {
    forall(30, |rng| {
        let k = 2 + rng.next_below(5) as usize;
        // lookahead 0 is the degenerate edge: the plan clamps it to one
        // tick so windows still advance
        let plan = WindowPlan::new(SimDuration(rng.next_below(500)));
        let lookahead = plan.lookahead();
        let mut engines: Vec<Engine<Ev>> = (0..k)
            .map(|_| Engine::with_queue(QueueKind::Wheel))
            .collect();
        let mut scheduled = 0u64;
        for (s, eng) in engines.iter_mut().enumerate() {
            if s >= 2 && s % 3 == 2 {
                continue; // permanently idle owner
            }
            for _ in 0..(1 + rng.next_below(20)) {
                let at = SimTime(rng.next_below(10_000));
                let ev = if rng.chance(0.2) { Ev::Fault } else { Ev::Local };
                eng.schedule(at, ev);
                scheduled += 1;
            }
        }
        let mut held: Vec<Vec<(SimTime, usize, u64, Ev)>> = vec![Vec::new(); k];
        let mut delivered: Vec<Vec<(SimTime, usize, u64)>> = vec![Vec::new(); k];
        let mut emit_seq = 0u64;
        let mut budget = 200u32;
        let mut processed = 0u64;
        let mut windows = 0u32;
        let mut last_tmin: Option<SimTime> = None;
        loop {
            let peeks: Vec<Option<SimTime>> = engines
                .iter_mut()
                .zip(&held)
                .map(|(e, h)| {
                    let held_min = h.iter().map(|m| m.0).min();
                    match (e.peek_time(), held_min) {
                        (Some(a), Some(b)) => Some(a.min(b)),
                        (a, b) => a.or(b),
                    }
                })
                .collect();
            let Some((t_min, wend)) = plan.next_window(&peeks) else {
                break;
            };
            prop(
                last_tmin.is_none_or(|p| t_min > p),
                "window failed to advance strictly",
            )?;
            last_tmin = Some(t_min);
            windows += 1;
            prop(windows < 100_000, "merge loop ran away (livelock)")?;
            for s in 0..k {
                let (mut batch, rest): (Vec<_>, Vec<_>) =
                    held[s].drain(..).partition(|m| m.0 < wend);
                held[s] = rest;
                sort_cross_messages(&mut batch);
                for (at, _, _, ev) in batch {
                    engines[s].schedule(at, ev);
                }
                while engines[s].peek_time().is_some_and(|t| t < wend) {
                    let (t, ev) = engines[s].next().expect("peeked");
                    processed += 1;
                    if let Ev::Cross { tester, emit } = ev {
                        delivered[s].push((t, tester, emit));
                    }
                    if budget > 0 && rng.chance(0.5) {
                        budget -= 1;
                        // a third of the traffic is zero-slack: latency
                        // exactly the lookahead bound
                        let extra = if rng.chance(0.3) {
                            0
                        } else {
                            rng.next_below(2_000)
                        };
                        let arrive = t + lookahead + SimDuration(extra);
                        prop(arrive >= wend, "conservative bound violated")?;
                        let tester = rng.next_below(64) as usize;
                        let dest = rng.next_below(k as u64) as usize;
                        held[dest].push((
                            arrive,
                            tester,
                            emit_seq,
                            Ev::Cross { tester, emit: emit_seq },
                        ));
                        emit_seq += 1;
                        scheduled += 1;
                    }
                }
            }
        }
        for d in &delivered {
            prop(
                d.windows(2).all(|w| w[0] <= w[1]),
                "cross delivery out of (time, tester, emit) order",
            )?;
        }
        prop(processed == scheduled, "events lost or duplicated")?;
        prop(
            held.iter().all(Vec::is_empty),
            "undelivered messages at quiescence",
        )
    });
}

#[test]
fn idle_owners_never_stall_the_window_loop() {
    // three owners, only the middle one has work: the plan must skip
    // the idle peeks, walk the loaded engine to quiescence and then
    // report no further window at all
    let plan = WindowPlan::new(SimDuration(100));
    let mut eng: Engine<u32> = Engine::with_queue(QueueKind::Wheel);
    for i in 0..5u32 {
        eng.schedule(SimTime(u64::from(i) * 250), i);
    }
    let mut got = Vec::new();
    loop {
        let peeks = [None, eng.peek_time(), None];
        let Some((_, wend)) = plan.next_window(&peeks) else {
            break;
        };
        while eng.peek_time().is_some_and(|t| t < wend) {
            got.push(eng.next().expect("peeked").1);
        }
    }
    assert_eq!(got, vec![0, 1, 2, 3, 4]);
    assert_eq!(plan.next_window(&[None, None, None]), None);
}
