//! Integration tests for the config system and the CLI plumbing
//! (run -> run-dir -> analyze -> predict round trip on disk).

use diperf::cli;
use diperf::config;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir()
        .join(format!("diperf_cli_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn sv(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

#[test]
fn config_file_end_to_end() {
    let dir = tmp_dir("cfg");
    let cfg_path = dir.join("exp.toml");
    std::fs::write(
        &cfg_path,
        "preset = \"quick_http\"\nseed = 5\n\
         [testbed]\nnum_testers = 3\n\
         [test]\nduration_s = 45.0\n",
    )
    .unwrap();
    let text = std::fs::read_to_string(&cfg_path).unwrap();
    let cfg = config::experiment_from_toml(&text).unwrap();
    assert_eq!(cfg.testbed.num_testers, 3);
    let r = diperf::experiment::run_experiment(&cfg);
    assert!(r.data.completed() > 20);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_then_analyze_then_predict_round_trip() {
    let dir = tmp_dir("run");
    let out = dir.join("myrun");
    let out_s = out.to_str().unwrap();
    // run retained (native path so this passes without artifacts);
    // samples.csv only exists on the retain path
    let code = cli::main(&sv(&[
        "run", "--preset", "quick_http", "--testers", "4", "--duration",
        "60", "--seed", "9", "--out", out_s, "--native", "--quiet",
        "--retain-samples",
    ]))
    .unwrap();
    assert_eq!(code, 0);
    for f in [
        "samples.csv",
        "summary.txt",
        "fig_timeline.csv",
        "fig_per_client.csv",
        "fig_poly.csv",
        "fig_timeline.gp",
    ] {
        assert!(out.join(f).exists(), "missing {f}");
    }
    // analyze the saved run
    let code = cli::main(&sv(&[
        "analyze", "--run", out_s, "--native", "--quiet",
    ]))
    .unwrap();
    assert_eq!(code, 0);
    // fit the empirical model
    let code = cli::main(&sv(&[
        "predict", "--run", out_s, "--native", "--rt-target", "1.0",
    ]))
    .unwrap();
    assert_eq!(code, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streaming_run_writes_figures_but_no_samples() {
    let dir = tmp_dir("stream");
    let out = dir.join("r");
    let bench = dir.join("bench.json");
    // streaming is the default; also exercise --queue and --bench-json
    let code = cli::main(&sv(&[
        "run", "--preset", "quick_http", "--testers", "3", "--duration",
        "40", "--seed", "4", "--out", out.to_str().unwrap(), "--quiet",
        "--queue", "wheel", "--bench-json", bench.to_str().unwrap(),
    ]))
    .unwrap();
    assert_eq!(code, 0);
    assert!(!out.join("samples.csv").exists(), "streaming retains nothing");
    for f in ["summary.txt", "fig_timeline.csv", "fig_per_client.csv"] {
        assert!(out.join(f).exists(), "missing {f}");
    }
    let summary = std::fs::read_to_string(out.join("summary.txt")).unwrap();
    assert!(summary.contains("collection        stream"));
    assert!(summary.contains("rt quantiles"));
    let json = std::fs::read_to_string(&bench).unwrap();
    assert!(json.contains("\"schema\": \"diperf-bench-scale-v1\""));
    assert!(json.contains("\"queue\":\"wheel\""));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn heap_and_wheel_cli_runs_produce_identical_figures() {
    let dir = tmp_dir("queues");
    let mk = |tag: &str, queue: &str| {
        let out = dir.join(tag);
        cli::main(&sv(&[
            "run", "--preset", "quick_http", "--testers", "3", "--duration",
            "40", "--seed", "11", "--out", out.to_str().unwrap(), "--quiet",
            "--queue", queue,
        ]))
        .unwrap();
        std::fs::read_to_string(out.join("fig_timeline.csv")).unwrap()
    };
    let wheel = mk("wheel", "wheel");
    let heap = mk("heap", "heap");
    assert_eq!(wheel, heap, "queue choice must not change the figures");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_dir_summary_mentions_service() {
    let dir = tmp_dir("sum");
    let out = dir.join("r");
    cli::main(&sv(&[
        "run", "--preset", "quick_http", "--testers", "2", "--duration",
        "30", "--out", out.to_str().unwrap(), "--native", "--quiet",
    ]))
    .unwrap();
    let summary = std::fs::read_to_string(out.join("summary.txt")).unwrap();
    assert!(summary.contains("apache-cgi"));
    assert!(summary.contains("sync error"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn timeline_csv_is_wellformed() {
    let dir = tmp_dir("csv");
    let out = dir.join("r");
    cli::main(&sv(&[
        "run", "--preset", "quick_http", "--testers", "3", "--duration",
        "40", "--out", out.to_str().unwrap(), "--native", "--quiet",
    ]))
    .unwrap();
    let csv = std::fs::read_to_string(out.join("fig_timeline.csv")).unwrap();
    let lines: Vec<&str> = csv.trim().lines().collect();
    assert_eq!(lines.len(), 1 + cli::NUM_QUANTA);
    assert!(lines[0].split(',').count() >= 7);
    for l in &lines[1..] {
        assert_eq!(l.split(',').count(), 7, "row: {l}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bad_flags_are_loud() {
    assert!(cli::main(&sv(&["run", "--bogus"])).is_err());
    assert!(cli::main(&sv(&["run", "--preset", "zzz"])).is_err());
    assert!(cli::main(&sv(&["analyze"])).is_err()); // missing --run
}
