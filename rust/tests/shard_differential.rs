//! The shard-count-invariance contract: for a fixed seed, the sharded
//! world must replay to **byte-identical** reports at every shard
//! count — `--shards 1` and `--shards 8` are the same experiment on a
//! different number of cores.  The hub is always a separate owner, the
//! conservative window boundaries depend only on the union of pending
//! event times, and cross-owner deliveries are merged in canonical
//! `(arrive, tester, emit)` order, so nothing observable may move.
//!
//! What is *not* invariant (and deliberately unasserted): raw engine
//! event counts and peak pending-queue depth, which are summed across
//! per-shard engines and shift with the partitioning.
//!
//! The same file pins the flattened single-engine hot path: the dense
//! ID-indexed world maps and the classic `FxHashMap` layout must replay
//! a seed to bit-identical samples and figures (the
//! `engine_queues.rs`-style differential, one layer up).

use diperf::analysis::{self, AnalysisInput};
use diperf::experiment::{
    presets, run_experiment_opts, ExperimentConfig, ExperimentResult, MapKind,
    RunOptions,
};
use diperf::metrics::CollectionMode;
use diperf::report;

fn run(
    cfg: &ExperimentConfig,
    shards: Option<usize>,
    collect: CollectionMode,
) -> ExperimentResult {
    run_experiment_opts(
        cfg,
        RunOptions {
            shards,
            collect,
            ..RunOptions::default()
        },
    )
}

/// Render the full figure set for a finished run: timeline CSV,
/// per-client CSV, availability CSV and the availability/fairness
/// summary block — on whichever collection path the run used.
fn figures(r: &ExperimentResult) -> (String, String, String, String) {
    let (out, churn) = match r.stream.as_ref() {
        Some(agg) => (
            analysis::output_from_binned(&agg.binned),
            analysis::churn_from_stream(agg, &r.data.testers),
        ),
        None => {
            let inp = AnalysisInput::from_grid(&r.data, &r.grid);
            let out =
                analysis::analyze(&inp, r.grid.num_quanta, r.grid.num_clients);
            (out, analysis::churn_report_grid(&r.data, &r.grid))
        }
    };
    (
        report::timeline_csv(&out, r.grid.t0, r.grid.quantum),
        report::per_client_csv(&out, &r.data),
        report::churn_csv(&churn, r.grid.t0, r.grid.quantum),
        report::churn_summary(&churn),
    )
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn assert_series_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(close(*x, *y, tol), "{what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn figures_are_byte_identical_at_every_shard_count() {
    // the acceptance matrix: churn (crashes + rejoins + evictions),
    // spike (mass crash) and soak (WAN, no scenario), each replayed at
    // 1/2/4/8 shards against the 1-shard baseline
    let cases: [(&str, ExperimentConfig); 3] = [
        ("churn", presets::churn_study(12, 90.0, 2024)),
        ("spike", presets::spike_study(12, 90.0, 2025)),
        ("soak", presets::soak(12, 90.0, 2026)),
    ];
    for (name, cfg) in &cases {
        let base = run(cfg, Some(1), CollectionMode::Stream);
        let want = figures(&base);
        assert!(
            base.stream.as_ref().unwrap().samples_seen > 50,
            "{name}: too little work to make the comparison meaningful"
        );
        for s in [2usize, 4, 8] {
            let r = run(cfg, Some(s), CollectionMode::Stream);
            // the experiment itself is invariant down to the bit level
            assert_eq!(
                r.data.duration_s.to_bits(),
                base.data.duration_s.to_bits(),
                "{name} S={s}: span"
            );
            assert_eq!(r.faults, base.faults, "{name} S={s}: faults");
            assert_eq!(
                r.data.dropped_unsynced, base.data.dropped_unsynced,
                "{name} S={s}: drops"
            );
            for (a, b) in r.data.testers.iter().zip(&base.data.testers) {
                assert_eq!(a.samples, b.samples, "{name} S={s}: samples");
                assert_eq!(a.evicted, b.evicted, "{name} S={s}: evicted");
                assert_eq!(a.rejoins, b.rejoins, "{name} S={s}: rejoins");
            }
            // and so are all four rendered reports, byte for byte
            let got = figures(&r);
            assert_eq!(got.0, want.0, "{name} S={s}: timeline csv");
            assert_eq!(got.1, want.1, "{name} S={s}: per-client csv");
            assert_eq!(got.2, want.2, "{name} S={s}: availability csv");
            assert_eq!(got.3, want.3, "{name} S={s}: churn summary");
        }
    }
}

#[test]
fn retained_samples_are_byte_identical_across_shard_counts() {
    // retain mode exposes every individual sample; the full samples.csv
    // must not move by a byte, including when the shard count exceeds
    // the tester count (it clamps to one tester per shard)
    let cfg = presets::churn_study(10, 80.0, 77);
    let base = run(&cfg, Some(1), CollectionMode::Retain);
    let want = report::samples_csv(&base.data);
    assert!(base.data.samples.len() > 50, "too few samples");
    for s in [3usize, 8, 64] {
        let r = run(&cfg, Some(s), CollectionMode::Retain);
        assert_eq!(report::samples_csv(&r.data), want, "S={s}: samples.csv");
        assert_eq!(figures(&r), figures(&base), "S={s}: figures");
    }
}

#[test]
fn sharded_streaming_matches_sharded_retained() {
    // collection is an observer in the sharded world too: a streaming
    // run and a retained run at the same shard count agree exactly on
    // every counting series and to rounding on the floating sums
    let cfg = presets::spike_study(10, 80.0, 5);
    let retain = run(&cfg, Some(4), CollectionMode::Retain);
    let stream = run(&cfg, Some(4), CollectionMode::Stream);
    let inp = AnalysisInput::from_grid(&retain.data, &retain.grid);
    let posthoc =
        analysis::analyze(&inp, retain.grid.num_quanta, retain.grid.num_clients);
    let agg = stream.stream.as_ref().expect("streaming aggregator");
    let streamed = analysis::output_from_binned(&agg.binned);
    assert_eq!(posthoc.tput, streamed.tput, "tput");
    assert_eq!(posthoc.completed, streamed.completed, "completed");
    assert_eq!(posthoc.util, streamed.util, "util");
    assert_eq!(posthoc.fairness, streamed.fairness, "fairness");
    assert_series_close(&posthoc.load, &streamed.load, 1e-9, "load");
    assert_series_close(&posthoc.rt_mean, &streamed.rt_mean, 1e-9, "rt_mean");
    let cr = analysis::churn_report_grid(&retain.data, &retain.grid);
    let cs = analysis::churn_from_stream(agg, &stream.data.testers);
    assert_eq!(cr.active, cs.active, "active");
    assert_eq!(cr.evicted, cs.evicted);
    assert_eq!(cr.rejoins, cs.rejoins);
    assert!(close(cr.jain_fairness, cs.jain_fairness, 1e-12));
    assert!(close(cr.mean_availability, cs.mean_availability, 1e-12));
}

#[test]
fn dense_and_hash_layouts_replay_bit_identically() {
    // the flattened hot path, pinned: dense ID-indexed vectors and the
    // classic FxHashMap world maps drive the *same* single-engine
    // simulation, so samples, figures and even the event count must
    // match bit for bit under a churn scenario
    let cfg = presets::churn_study(12, 90.0, 31);
    let dense = run_experiment_opts(
        &cfg,
        RunOptions {
            map: MapKind::Dense,
            ..RunOptions::default()
        },
    );
    let hash = run_experiment_opts(
        &cfg,
        RunOptions {
            map: MapKind::Hash,
            ..RunOptions::default()
        },
    );
    assert_eq!(dense.events, hash.events, "event count");
    assert_eq!(dense.peak_pending, hash.peak_pending, "peak pending");
    assert_eq!(
        report::samples_csv(&dense.data),
        report::samples_csv(&hash.data),
        "samples.csv"
    );
    assert_eq!(figures(&dense), figures(&hash), "figures");
}
