//! HTTP/1.1 conformance + property suite for the live protocol layer
//! (`live::proto::http11`).
//!
//! Three rings, all with **zero sockets and zero sleeps**:
//!
//! 1. **Golden transcripts** — byte-exact request/response fixtures in
//!    `rust/tests/fixtures/http11/` replayed whole, torn at *every*
//!    byte boundary, and dribbled one byte at a time; the parse result
//!    must be identical under every tearing.
//! 2. **Properties** — seeded random trials ([`diperf::util::proptest`]):
//!    arbitrary bytes never panic either parser, and generated response
//!    pipelines survive arbitrary split points and re-serialize
//!    byte-exactly.
//! 3. **The reactor, for real** — the identical parser state machine
//!    driven through the readiness loop under
//!    [`diperf::live::reactor::testing::MockNet`], covering keep-alive
//!    reuse, torn responses, `Connection: close`, status-code
//!    accounting, garbage poisoning and unsolicited-response resync.

use diperf::live::proto::http11::{
    write_request, write_response, ReqParser, RespParser, Response,
};
use diperf::live::proto::{client_for, ProtocolKind};
use diperf::live::reactor::testing::{MockClock, MockNet};
use diperf::live::reactor::{AgentSpec, Endpoint, TargetMode, Worker};
use diperf::live::wire::{self, FrameBuf, WireUp};
use diperf::metrics::SampleOutcome;
use diperf::transport::{CtrlMsg, TestDescription};
use diperf::util::proptest::{forall, gen_vec, prop};

// ---------------------------------------------------------------------------
// Golden fixtures
// ---------------------------------------------------------------------------

const REQUEST_KEEPALIVE: &[u8] =
    include_bytes!("fixtures/http11/request_keepalive.bin");
const REQUEST_CLOSE: &[u8] = include_bytes!("fixtures/http11/request_close.bin");
const SIMPLE_200: &[u8] = include_bytes!("fixtures/http11/simple_200.bin");
const CHUNKED_TRAILERS: &[u8] =
    include_bytes!("fixtures/http11/chunked_trailers.bin");
const PIPELINED_THREE: &[u8] =
    include_bytes!("fixtures/http11/pipelined_three.bin");
const INTERIM_100: &[u8] = include_bytes!("fixtures/http11/interim_100.bin");
const CLOSE_EOF: &[u8] = include_bytes!("fixtures/http11/close_eof.bin");

/// Expected response: `(status, body, close, interim)`.
type ExpResp = (u16, &'static [u8], bool, u32);

/// Every response-transcript fixture with its expected parse:
/// `(name, bytes, needs_eof, responses)`.
fn transcripts() -> Vec<(&'static str, &'static [u8], bool, Vec<ExpResp>)> {
    vec![
        ("simple_200", SIMPLE_200, false, vec![(200, b"ok\n", false, 0)]),
        (
            "chunked_trailers",
            CHUNKED_TRAILERS,
            false,
            vec![(200, b"wikipedia", false, 0)],
        ),
        (
            "pipelined_three",
            PIPELINED_THREE,
            false,
            vec![
                (200, b"ok\n", false, 0),
                (503, b"denied\n", false, 0),
                (500, b"error\n", true, 0),
            ],
        ),
        ("interim_100", INTERIM_100, false, vec![(200, b"done", false, 1)]),
        (
            "close_eof",
            CLOSE_EOF,
            true,
            vec![(200, b"streamed until close", true, 0)],
        ),
    ]
}

/// Feed a transcript in the given pieces and collect every completed
/// response (capturing bodies).
fn parse_transcript(pieces: &[&[u8]], needs_eof: bool) -> Vec<Response> {
    let mut p = RespParser::capturing();
    for piece in pieces {
        p.feed(piece).expect("fixture bytes parse");
    }
    if needs_eof {
        p.eof().expect("EOF is legal at the end of this transcript");
    }
    assert!(!p.mid_message(), "transcript must end on a message boundary");
    std::iter::from_fn(move || p.pop()).collect()
}

fn assert_responses(name: &str, tearing: &str, got: &[Response], want: &[ExpResp]) {
    assert_eq!(got.len(), want.len(), "{name} ({tearing}): response count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.status, w.0, "{name}[{i}] ({tearing}): status");
        assert_eq!(g.body, w.1, "{name}[{i}] ({tearing}): body");
        assert_eq!(
            g.body_len,
            w.1.len() as u64,
            "{name}[{i}] ({tearing}): body_len"
        );
        assert_eq!(g.close, w.2, "{name}[{i}] ({tearing}): close");
        assert_eq!(g.interim, w.3, "{name}[{i}] ({tearing}): interim count");
    }
}

#[test]
fn golden_transcripts_parse_to_the_expected_responses() {
    for (name, bytes, needs_eof, want) in transcripts() {
        let got = parse_transcript(&[bytes], needs_eof);
        assert_responses(name, "whole", &got, &want);
    }
}

#[test]
fn transcripts_parse_identically_at_every_tear_point() {
    for (name, bytes, needs_eof, want) in transcripts() {
        // torn into two pieces at every byte boundary
        for split in 0..=bytes.len() {
            let got =
                parse_transcript(&[&bytes[..split], &bytes[split..]], needs_eof);
            assert_responses(name, &format!("split at {split}"), &got, &want);
        }
        // the worst case: one byte per read
        let singles: Vec<&[u8]> = bytes.chunks(1).collect();
        let got = parse_transcript(&singles, needs_eof);
        assert_responses(name, "1-byte dribble", &got, &want);
    }
}

#[test]
fn content_length_transcripts_reserialize_byte_exact() {
    // fixtures in the serializer's own form must round-trip through
    // parse → write_response with zero byte drift
    for (name, bytes) in [
        ("simple_200", SIMPLE_200),
        ("pipelined_three", PIPELINED_THREE),
    ] {
        let got = parse_transcript(&[bytes], false);
        let mut reser = Vec::new();
        for r in &got {
            write_response(&mut reser, r.status, &r.body, r.close);
        }
        assert_eq!(reser, bytes, "{name}: byte-exact re-serialization");
    }
}

#[test]
fn golden_request_bytes_match_the_serializer() {
    let mut req = Vec::new();
    write_request(&mut req, 7, false);
    assert_eq!(req, REQUEST_KEEPALIVE, "keep-alive request drifted");
    req.clear();
    write_request(&mut req, 8, true);
    assert_eq!(req, REQUEST_CLOSE, "close request drifted");
}

#[test]
fn requests_round_trip_through_the_target_side_parser() {
    let mut stream = REQUEST_KEEPALIVE.to_vec();
    stream.extend_from_slice(REQUEST_CLOSE);
    for split in 0..=stream.len() {
        let mut q = ReqParser::new();
        q.feed(&stream[..split]).expect("request bytes parse");
        q.feed(&stream[split..]).expect("request bytes parse");
        let a = q.pop().expect("first request");
        let b = q.pop().expect("second request");
        assert!(q.pop().is_none());
        assert!(!q.mid_message());
        assert_eq!(
            (a.method.as_str(), a.target.as_str(), a.close, a.body_len),
            ("GET", "/diperf?seq=7", false, 0),
            "split at {split}"
        );
        assert_eq!(
            (b.method.as_str(), b.target.as_str(), b.close, b.body_len),
            ("GET", "/diperf?seq=8", true, 0),
            "split at {split}"
        );
    }
}

#[test]
fn http11_client_maps_status_codes_onto_the_outcome_taxonomy() {
    let mut c = client_for(ProtocolKind::Http11);
    let mut req = Vec::new();
    c.emit_request(&mut req, 7);
    assert_eq!(
        req, REQUEST_KEEPALIVE,
        "the client engine always requests keep-alive"
    );

    let cases: [(u16, SampleOutcome); 6] = [
        (200, SampleOutcome::Success),
        (204, SampleOutcome::Success),
        (429, SampleOutcome::Denied),
        (503, SampleOutcome::Denied),
        (400, SampleOutcome::ServiceError),
        (500, SampleOutcome::ServiceError),
    ];
    for (status, outcome) in cases {
        let body: &[u8] = if status == 204 { b"" } else { b"x" };
        let mut bytes = Vec::new();
        write_response(&mut bytes, status, body, false);
        c.on_bytes(&bytes).expect("well-formed response");
        let v = c.next_verdict().expect("one verdict per response");
        assert_eq!(v.outcome, outcome, "status {status}");
        assert!(!v.close, "status {status}: keep-alive response");
    }
    assert!(c.next_verdict().is_none(), "no verdict owed");
}

// ---------------------------------------------------------------------------
// Properties
// ---------------------------------------------------------------------------

#[test]
fn arbitrary_bytes_never_panic_either_parser() {
    // half HTTP-flavoured bytes (to reach the deep parser states), half
    // raw noise; the parsers must either accept or return ProtoError —
    // never panic, never loop
    let alphabet: &[u8] = b"HTTP/1.0 2045x\r\n:; -OKContent-LghTransfer\tEncoding";
    forall(400, |rng| {
        let bytes = gen_vec(rng, 0..600, |r| {
            if r.chance(0.7) {
                alphabet[r.next_below(alphabet.len() as u64) as usize]
            } else {
                r.next_u64() as u8
            }
        });
        let mut p = RespParser::capturing();
        let mut q = ReqParser::new();
        let fed = p.feed(&bytes);
        let _ = q.feed(&bytes);
        while q.pop().is_some() {}
        if fed.is_ok() {
            let _ = p.eof();
            while p.pop().is_some() {}
        }
        prop(true, "parsers never panic")
    });
}

#[test]
fn generated_pipelines_survive_arbitrary_tearing_and_reserialize() {
    const STATUSES: [u16; 6] = [200, 400, 404, 418, 500, 503];
    forall(250, |rng| {
        // a pipeline of 1..=3 responses with arbitrary binary bodies;
        // only the last may carry Connection: close (a real stream ends
        // there)
        let n = 1 + rng.next_below(3) as usize;
        let mut stream = Vec::new();
        let mut want: Vec<(u16, Vec<u8>, bool)> = Vec::new();
        for k in 0..n {
            let status = STATUSES[rng.next_below(STATUSES.len() as u64) as usize];
            let body = gen_vec(rng, 0..48, |r| r.next_u64() as u8);
            let close = k == n - 1 && rng.chance(0.5);
            write_response(&mut stream, status, &body, close);
            want.push((status, body, close));
        }

        let split = rng.next_below(stream.len() as u64 + 1) as usize;
        let mut p = RespParser::capturing();
        p.feed(&stream[..split]).map_err(|e| e.to_string())?;
        p.feed(&stream[split..]).map_err(|e| e.to_string())?;
        let got: Vec<Response> = std::iter::from_fn(|| p.pop()).collect();

        prop(got.len() == want.len(), "every pipelined response surfaces")?;
        let mut reser = Vec::new();
        for (g, w) in got.iter().zip(&want) {
            prop(g.status == w.0, "status preserved")?;
            prop(g.body == w.1, "body preserved across the tear")?;
            prop(g.close == w.2, "close flag preserved")?;
            write_response(&mut reser, g.status, &g.body, g.close);
        }
        prop(reser == stream, "byte-exact re-serialization")
    });
}

// ---------------------------------------------------------------------------
// The reactor under MockNet: the same parser behind the readiness loop
// ---------------------------------------------------------------------------

/// One worker over the mock fabric plus the handles to script it
/// (the `live_reactor.rs` rig, at `TargetMode::Http11`).
struct Rig {
    net: MockNet,
    clock: MockClock,
    w: Worker<MockNet, MockClock>,
}

impl Rig {
    fn new() -> Rig {
        let specs = [AgentSpec {
            id: 0,
            skew_s: 0.0,
            drift: 0.0,
        }];
        let net = MockNet::new();
        let clock = MockClock::new();
        let w = Worker::new(net.clone(), clock.clone(), &specs, TargetMode::Http11);
        Rig { net, clock, w }
    }

    /// Advance time and run one event-loop turn.
    fn step(&mut self, dt: f64) {
        self.clock.advance(dt);
        self.w.tick(None).expect("mock wait never fails");
    }

    /// Step until the worker is done (bounded: a livelock fails, not
    /// hangs).
    fn settle(&mut self) {
        for _ in 0..1000 {
            if self.w.all_done() {
                return;
            }
            self.step(0.001);
        }
        panic!("worker did not finish within 1000 steps");
    }

    fn ctrl(&self) -> u64 {
        self.net.tokens(Endpoint::Ctrl)[0]
    }

    fn ts(&self) -> u64 {
        let toks = self.net.tokens(Endpoint::TimeServer);
        *toks.last().expect("ts link exists")
    }
}

/// A controller frame as it appears on the wire.
fn ctrl_frame(msg: &CtrlMsg) -> Vec<u8> {
    let p = wire::encode_ctrl(msg);
    let mut out = (p.len() as u32).to_be_bytes().to_vec();
    out.extend_from_slice(&p);
    out
}

/// A time-server stamp as it appears on the wire.
fn stamp(server_s: f64) -> [u8; 8] {
    server_s.to_bits().to_be_bytes()
}

fn decode_frames(bytes: &[u8]) -> Vec<WireUp> {
    let mut fb = FrameBuf::new();
    fb.push(bytes);
    let mut out = Vec::new();
    while let Some(p) = fb.pop().expect("well-formed frames") {
        out.push(wire::decode_up(&p).expect("decodable frame"));
    }
    assert_eq!(fb.pending(), 0, "trailing partial frame");
    out
}

fn desc(duration_s: f64, give_up: u32) -> TestDescription {
    TestDescription {
        duration_s,
        client_interval_s: 0.0,
        sync_interval_s: 1.0,
        rate_cap_per_s: f64::INFINITY,
        timeout_s: 5.0,
        give_up_failures: give_up,
    }
}

/// Drive the rig through handshake → Start → probe → first sync,
/// leaving it Running with a launch armed.  Returns `(ctrl, target)`
/// tokens.
fn to_running(rig: &mut Rig, d: TestDescription) -> (u64, u64) {
    rig.step(0.001); // connects resolve, Hello + DeployDone drain
    let ctrl = rig.ctrl();
    let hs = decode_frames(&rig.net.take_outbound(ctrl));
    assert!(matches!(hs[0], WireUp::Hello { agent: 0 }), "{hs:?}");
    assert!(matches!(hs[1], WireUp::DeployDone), "{hs:?}");

    rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Start(d)));
    rig.step(0.001); // Start read; latency probe begins
    let tgt = *rig.net.tokens(Endpoint::Target).last().unwrap();
    rig.step(0.001); // probe connect resolves; sync requested
    assert_eq!(rig.net.take_outbound(rig.ts()), vec![1u8]);
    rig.net.deliver(rig.ts(), &stamp(1000.0));
    rig.step(0.001); // sync completes; first launch armed
    let frames = decode_frames(&rig.net.take_outbound(ctrl));
    assert!(
        frames.iter().any(|f| matches!(f, WireUp::Sync(_))),
        "expected a Sync frame, got {frames:?}"
    );
    (ctrl, tgt)
}

/// Collect every sample across all Samples frames.
fn all_samples(frames: &[WireUp]) -> Vec<diperf::metrics::CallSample> {
    frames
        .iter()
        .filter_map(|f| match f {
            WireUp::Samples(v) => Some(v.clone()),
            _ => None,
        })
        .flatten()
        .collect()
}

/// The bytes must be exactly one serialized agent GET (any seq).
fn assert_get(bytes: &[u8]) {
    let text = String::from_utf8_lossy(bytes);
    assert!(
        bytes.starts_with(b"GET /diperf?seq="),
        "not an agent GET: {text:?}"
    );
    assert!(
        bytes.ends_with(b"Connection: keep-alive\r\n\r\n"),
        "agent calls are keep-alive: {text:?}"
    );
}

fn resp(status: u16, body: &[u8], close: bool) -> Vec<u8> {
    let mut v = Vec::new();
    write_response(&mut v, status, body, close);
    v
}

#[test]
fn reactor_http11_accounts_statuses_end_to_end() {
    let mut rig = Rig::new();
    let (ctrl, tgt) = to_running(&mut rig, desc(30.0, 0));

    rig.step(0.001); // launch #1 writes a real GET
    assert_get(&rig.net.take_outbound(tgt));
    let replies: [(u16, &[u8]); 3] =
        [(200, b"ok\n"), (503, b"denied\n"), (500, b"error\n")];
    for (status, body) in replies {
        rig.net.deliver(tgt, &resp(status, body, false));
        rig.step(0.001); // response → status-coded sample; relaunch armed
        rig.step(0.001); // next launch fires on the kept-alive connection
        assert_get(&rig.net.take_outbound(tgt));
    }
    assert_eq!(
        rig.net.tokens(Endpoint::Target).len(),
        1,
        "keep-alive must reuse one connection across calls"
    );

    rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Stop));
    rig.step(0.001);
    rig.settle();
    let samples = all_samples(&decode_frames(&rig.net.take_outbound(ctrl)));
    let outcomes: Vec<SampleOutcome> = samples.iter().map(|s| s.outcome).collect();
    assert_eq!(
        outcomes,
        vec![
            SampleOutcome::Success,
            SampleOutcome::Denied,
            SampleOutcome::ServiceError
        ],
        "2xx → Success, 503 → Denied, 500 → ServiceError"
    );
}

#[test]
fn reactor_http11_torn_response_completes_only_on_the_last_byte() {
    let mut rig = Rig::new();
    let (_ctrl, tgt) = to_running(&mut rig, desc(30.0, 0));

    rig.step(0.001);
    assert_get(&rig.net.take_outbound(tgt));
    let bytes = resp(200, b"torn across many reads", false);
    for b in &bytes[..bytes.len() - 1] {
        rig.net.deliver(tgt, &[*b]);
        rig.step(0.001);
        assert!(
            rig.net.take_outbound(tgt).is_empty(),
            "no relaunch before the response completes"
        );
    }
    rig.net.deliver(tgt, &bytes[bytes.len() - 1..]);
    rig.step(0.001); // final byte → verdict → sample; relaunch armed
    rig.step(0.001); // launch #2
    assert_get(&rig.net.take_outbound(tgt));
}

#[test]
fn reactor_http11_connection_close_opens_a_fresh_target() {
    let mut rig = Rig::new();
    let (_ctrl, tgt) = to_running(&mut rig, desc(30.0, 0));

    rig.step(0.001);
    assert_get(&rig.net.take_outbound(tgt));
    rig.net.deliver(tgt, &resp(200, b"bye", true));
    rig.step(0.001); // Success sample; Connection: close honored
    assert!(
        !rig.net.is_open(tgt),
        "Connection: close tears the transport down"
    );
    rig.step(0.001); // launch #2 opens a fresh connection
    let tgt2 = *rig.net.tokens(Endpoint::Target).last().unwrap();
    assert_ne!(tgt, tgt2, "the next call needs a new transport");
    rig.step(0.001); // connect resolves; request written
    assert_get(&rig.net.take_outbound(tgt2));
}

#[test]
fn reactor_http11_garbage_poisons_the_connection() {
    let mut rig = Rig::new();
    let (ctrl, tgt) = to_running(&mut rig, desc(30.0, 0));

    rig.step(0.001);
    assert_get(&rig.net.take_outbound(tgt));
    rig.net.deliver(tgt, b"ICMP/9 haha\r\n\r\n");
    rig.step(0.001); // ProtoError → drop the connection, ServiceError
    assert!(
        !rig.net.is_open(tgt),
        "a protocol violation poisons the connection"
    );

    rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Stop));
    rig.step(0.001);
    rig.settle();
    let samples = all_samples(&decode_frames(&rig.net.take_outbound(ctrl)));
    assert_eq!(samples.len(), 1, "{samples:?}");
    assert_eq!(samples[0].outcome, SampleOutcome::ServiceError);
}

#[test]
fn reactor_http11_unsolicited_response_resyncs_by_dropping() {
    let mut rig = Rig::new();
    let (ctrl, tgt) = to_running(&mut rig, desc(30.0, 0));

    rig.step(0.001); // launch #1
    assert_get(&rig.net.take_outbound(tgt));
    // the target answers the single outstanding GET *twice*: the second
    // response is unsolicited, and the agent must resync by dropping
    // the connection rather than inventing a sample
    let mut two = resp(200, b"yours", false);
    two.extend_from_slice(&resp(200, b"nobody's", false));
    rig.net.deliver(tgt, &two);
    rig.step(0.001);
    assert!(
        !rig.net.is_open(tgt),
        "an unsolicited response must drop the connection"
    );

    rig.net.deliver(ctrl, &ctrl_frame(&CtrlMsg::Stop));
    rig.step(0.001);
    rig.settle();
    let samples = all_samples(&decode_frames(&rig.net.take_outbound(ctrl)));
    assert_eq!(
        samples.len(),
        1,
        "only the owed verdict becomes a sample: {samples:?}"
    );
    assert_eq!(samples[0].outcome, SampleOutcome::Success);
}
