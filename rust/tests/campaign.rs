//! Campaign determinism suite: the byte-identity contract under
//! parallelism, model-validation determinism per seed, and the
//! end-to-end held-out accuracy story on a grid that actually
//! saturates its service.

use diperf::campaign::{self, report, CampaignSpec, ServiceSel};
use diperf::config;

/// A small hostile grid: two services, three load levels, churn, LAN.
fn small_spec() -> CampaignSpec {
    let mut s = campaign::spec::by_name("campaign_smoke", 7).unwrap();
    s.duration_s = 120.0;
    s.validate().unwrap();
    s
}

#[test]
fn jobs_do_not_change_the_report_bytes() {
    let spec = small_spec();
    let serial = campaign::run(&spec, 1).unwrap();
    let parallel = campaign::run(&spec, 8).unwrap();
    assert_eq!(serial.cells.len(), spec.num_cells());
    assert_eq!(
        report::comparison_csv(&serial.cells),
        report::comparison_csv(&parallel.cells),
        "comparison CSV must be byte-identical across job counts"
    );
    assert_eq!(
        report::load_response_csv(&serial.spec, &serial.cells),
        report::load_response_csv(&parallel.spec, &parallel.cells),
    );
    assert_eq!(
        report::model_error_csv(&serial.models),
        report::model_error_csv(&parallel.models),
        "model-error CSV must be byte-identical across job counts"
    );
    assert_eq!(
        report::models_json(&serial.spec.name, &serial.models),
        report::models_json(&parallel.spec.name, &parallel.models),
        "serialized models must be byte-identical across job counts"
    );
}

#[test]
fn model_error_is_deterministic_per_seed_and_moves_with_it() {
    let spec = small_spec();
    let a = campaign::run(&spec, 3).unwrap();
    let b = campaign::run(&spec, 2).unwrap();
    assert!(!a.models.is_empty());
    for (x, y) in a.models.iter().zip(&b.models) {
        assert_eq!(x.service, y.service);
        assert_eq!(x.err.mae_s.to_bits(), y.err.mae_s.to_bits());
        assert_eq!(x.err.rms_s.to_bits(), y.err.rms_s.to_bits());
        assert_eq!(x.err.rel.to_bits(), y.err.rel.to_bits());
        assert_eq!(x.model.rt_coef, y.model.rt_coef);
    }
    // a different seed axis is a different (but still deterministic)
    // campaign
    let mut other = spec.clone();
    other.seeds = vec![8];
    let c = campaign::run(&other, 3).unwrap();
    assert_ne!(
        report::comparison_csv(&a.cells),
        report::comparison_csv(&c.cells),
        "seed must matter"
    );
}

#[test]
fn campaign_reports_per_service_holdout_error() {
    let spec = small_spec();
    let c = campaign::run(&spec, 4).unwrap();
    // both services got a validated model: fit on {3,9}, scored on {6}
    assert_eq!(c.models.len(), 2);
    for m in &c.models {
        assert_eq!(m.train_loads, vec![3, 9]);
        assert_eq!(m.holdout_loads, vec![6]);
        assert!(m.err.weight > 0.0, "{}: empty hold-out", m.service);
        assert!(
            m.err.mae_s.is_finite() && m.err.rms_s.is_finite(),
            "{}: non-finite error",
            m.service
        );
    }
    // the summary carries the per-service error lines
    let s = report::summary(&c);
    for m in &c.models {
        assert!(s.contains(m.service), "summary misses {}", m.service);
    }
    assert!(s.contains("held-out rt MAE"));
}

#[test]
fn saturating_http_grid_validates_with_a_knee() {
    // Apache/CGI with default calibration CPU-saturates well inside a
    // 20-tester ramp at 5 req/s each; the model fitted on alternate
    // load levels must predict the held-out levels' RT within a loose
    // bound.  (The exact-knee agreement bound lives in the
    // synthetic-service unit test, campaign::tests::
    // holdout_validation_on_a_known_knee, where ground truth is known
    // by construction.)
    let mut spec = CampaignSpec::new("http_knee");
    spec.services = vec![ServiceSel::Http];
    spec.loads = vec![4, 8, 12, 16, 20];
    spec.seeds = vec![11];
    spec.duration_s = 180.0;
    spec.stagger_s = 3.0;
    spec.client_interval_s = 0.2;
    spec.lan = true;
    spec.validate().unwrap();
    let c = campaign::run(&spec, 4).unwrap();
    assert_eq!(c.models.len(), 1);
    let m = &c.models[0];
    assert!(m.err.weight > 0.0);
    assert!(
        m.err.rel < 0.6,
        "held-out relative RT error too large: {}",
        m.err.rel
    );
    // models serialize and come back bit-exact
    let back =
        diperf::predict::PerfModel::from_json(&m.model.to_json()).unwrap();
    assert_eq!(m.model.rt_coef, back.rt_coef);
    assert_eq!(m.model.knee, back.knee);
}

#[test]
fn campaign_toml_round_trips_through_the_runner() {
    let spec = config::campaign_from_toml(
        "[campaign]\npreset = \"campaign_smoke\"\nloads = \"2,4\"\n\
         duration_s = 60.0\nscenarios = \"none\"\n",
    )
    .unwrap();
    assert_eq!(spec.loads, vec![2, 4]);
    let c = campaign::run(&spec, 2).unwrap();
    assert_eq!(c.cells.len(), 2 * 2);
    let csv = report::comparison_csv(&c.cells);
    // grid order: gram_prews rows before http rows, loads ascending
    let lines: Vec<&str> = csv.trim().lines().collect();
    assert_eq!(lines.len(), 1 + 4);
    assert!(lines[1].starts_with("gt3.2-prews-gram,none,2,"));
    assert!(lines[2].starts_with("gt3.2-prews-gram,none,4,"));
    assert!(lines[3].starts_with("apache-cgi,none,2,"));
}

#[test]
fn unknown_axis_names_fail_loudly_with_the_alternatives() {
    let e = campaign::spec::by_name("zzz", 1).unwrap_err().to_string();
    assert!(e.contains("gram_comparison") && e.contains("campaign_smoke"), "{e}");
    let e = config::preset_by_name("zzz", 1).unwrap_err().to_string();
    assert!(e.contains("quick_http") && e.contains("bench_scale"), "{e}");
    let e = diperf::scenario::by_name("zzz", 60.0).unwrap_err();
    assert!(e.contains("churn") && e.contains("flaky-service"), "{e}");
}
