"""Moving-average and polyfit kernels vs oracles."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import moving_average, gram, cholesky_solve, polyfit
from compile.kernels.ref import (moving_average_ref, gram_ref, polyfit_ref,
                                 polyval_ref)


class TestMovingAverage:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        q = 128
        num = rng.uniform(0, 100, q).astype(np.float32)
        den = rng.integers(0, 10, q).astype(np.float32)
        got = moving_average(num, den, 8.0)
        want = moving_average_ref(num, den, 8.0)
        np.testing.assert_allclose(np.array(got), want, rtol=1e-5,
                                   atol=1e-5)

    def test_zero_window_is_pointwise(self):
        rng = np.random.default_rng(1)
        q = 64
        num = rng.uniform(0, 10, q).astype(np.float32)
        den = np.ones(q, np.float32)
        got = np.array(moving_average(num, den, 0.0))
        np.testing.assert_allclose(got, num, rtol=1e-6)

    def test_full_window_is_global_mean(self):
        rng = np.random.default_rng(2)
        q = 64
        num = rng.uniform(0, 10, q).astype(np.float32)
        den = np.ones(q, np.float32)
        got = np.array(moving_average(num, den, float(q)))
        np.testing.assert_allclose(got, np.full(q, num.mean()), rtol=1e-5)

    def test_empty_denominator_guard(self):
        q = 32
        num = np.zeros(q, np.float32)
        den = np.zeros(q, np.float32)
        got = np.array(moving_average(num, den, 4.0))
        assert np.isfinite(got).all() and (got == 0).all()

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           q=st.sampled_from([32, 128, 512]),
           half=st.floats(0.0, 64.0))
    def test_hypothesis_sweep(self, seed, q, half):
        rng = np.random.default_rng(seed)
        num = rng.uniform(0, 100, q).astype(np.float32)
        den = rng.integers(0, 5, q).astype(np.float32)
        got = np.array(moving_average(num, den, half))
        want = moving_average_ref(num, den, half)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestPolyfit:
    def test_gram_matches_ref(self):
        rng = np.random.default_rng(0)
        q = 256
        x = rng.uniform(-1, 1, q).astype(np.float32)
        y = rng.uniform(-5, 5, q).astype(np.float32)
        w = rng.uniform(0, 3, q).astype(np.float32)
        a, b = gram(x, y, w, degree=6)
        ar, br = gram_ref(x, y, w, 6)
        np.testing.assert_allclose(np.array(a), ar, rtol=2e-4, atol=1e-3)
        np.testing.assert_allclose(np.array(b), br, rtol=2e-4, atol=1e-3)

    def test_cholesky_solve_vs_numpy(self):
        rng = np.random.default_rng(4)
        for n in (2, 4, 7, 8):
            m = rng.normal(size=(n, n))
            a = (m @ m.T + n * np.eye(n)).astype(np.float32)
            b = rng.normal(size=n).astype(np.float32)
            got = np.array(cholesky_solve(a, b))
            want = np.linalg.solve(a.astype(np.float64), b)
            np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_recovers_exact_polynomial(self):
        q = 512
        x = np.linspace(-1, 1, q).astype(np.float32)
        coef_true = np.array([3.0, -1.0, 2.0, 0.5], np.float32)
        y = polyval_ref(coef_true, x).astype(np.float32)
        got = np.array(polyfit(x, y, np.ones(q, np.float32), degree=3))
        # check fit quality in value space (f32 normal equations)
        err = np.abs(polyval_ref(got, x) - y).max()
        assert err < 1e-2

    def test_weights_mask_outliers(self):
        q = 256
        x = np.linspace(-1, 1, q).astype(np.float32)
        y = (2.0 + x).astype(np.float32)
        y_corrupt = y.copy()
        y_corrupt[::10] = 1e3
        w = np.ones(q, np.float32)
        w[::10] = 0.0
        got = np.array(polyfit(x, y_corrupt, w, degree=1))
        assert abs(got[0] - 2.0) < 1e-2 and abs(got[1] - 1.0) < 1e-2

    def test_degenerate_few_points_finite(self):
        # fewer weighted points than coefficients: ridge keeps it finite
        q = 64
        x = np.linspace(-1, 1, q).astype(np.float32)
        y = np.ones(q, np.float32)
        w = np.zeros(q, np.float32)
        w[3] = 1.0
        got = np.array(polyfit(x, y, w, degree=6))
        assert np.isfinite(got).all()

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           degree=st.integers(1, 6),
           q=st.sampled_from([64, 256, 512]))
    def test_hypothesis_fit_quality(self, seed, degree, q):
        """Kernel fit is as good as the f64 reference fit (in value space)."""
        rng = np.random.default_rng(seed)
        x = np.linspace(-1, 1, q).astype(np.float32)
        coef = rng.uniform(-2, 2, degree + 1)
        y = polyval_ref(coef, x).astype(np.float32)
        w = np.ones(q, np.float32)
        got = np.array(polyfit(x, y, w, degree=degree))
        ref = polyfit_ref(x, y, w, degree)
        err_got = np.abs(polyval_ref(got, x) - y).max()
        err_ref = np.abs(polyval_ref(ref, x) - y).max()
        assert err_got <= max(5 * err_ref, 5e-2)
