"""Pallas binning kernels vs the pure-numpy oracle (the CORE L1 signal)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bin_samples, bin_clients, BLOCK_S
from compile.kernels.ref import bin_samples_ref, bin_clients_ref


def make_samples(rng, s, n_real, t_max=500.0, rt_max=30.0, n_clients=20):
    ts = rng.uniform(0, t_max, s).astype(np.float32)
    rt = rng.uniform(0.05, rt_max, s).astype(np.float32)
    te = (ts + rt).astype(np.float32)
    ok = (rng.random(s) < 0.9).astype(np.float32)
    valid = np.zeros(s, np.float32)
    valid[:n_real] = 1.0
    cid = rng.integers(0, n_clients, s).astype(np.float32)
    return ts, te, rt, ok, valid, cid


class TestBinSamples:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        ts, te, rt, ok, valid, _ = make_samples(rng, 2 * BLOCK_S, 3000)
        q = 64
        got = bin_samples(ts, te, rt, ok, valid, 0.0, 10.0, num_quanta=q)
        want = bin_samples_ref(ts, te, rt, ok, valid, 0.0, 10.0, q)
        np.testing.assert_allclose(np.array(got[0]), want[0], atol=1e-5)
        np.testing.assert_allclose(np.array(got[1]), want[1], rtol=1e-5,
                                   atol=1e-4)
        np.testing.assert_allclose(np.array(got[2]), want[2], rtol=1e-4,
                                   atol=1e-3)

    def test_all_padding(self):
        z = np.zeros(BLOCK_S, np.float32)
        tput, rtsum, load = bin_samples(z, z, z, z, z, 0.0, 1.0,
                                        num_quanta=32)
        assert np.array(tput).sum() == 0.0
        assert np.array(rtsum).sum() == 0.0
        assert np.array(load).sum() == 0.0

    def test_single_sample(self):
        z = np.zeros(BLOCK_S, np.float32)
        ts, te, rt = z.copy(), z.copy(), z.copy()
        ok, valid = z.copy(), z.copy()
        ts[0], rt[0], te[0] = 5.0, 2.0, 7.0
        ok[0] = valid[0] = 1.0
        tput, rtsum, load = bin_samples(ts, te, rt, ok, valid, 0.0, 1.0,
                                        num_quanta=16)
        tput = np.array(tput)
        # completion lands in quantum 7 (te = 7.0 -> bin 7)
        assert tput[7] == 1.0 and tput.sum() == 1.0
        assert abs(np.array(rtsum)[7] - 2.0) < 1e-6
        # in flight exactly over quanta 5 and 6
        load = np.array(load)
        np.testing.assert_allclose(load[5:7], [1.0, 1.0], atol=1e-5)
        assert load.sum() == pytest.approx(2.0, abs=1e-4)

    def test_failures_count_in_load_not_tput(self):
        z = np.zeros(BLOCK_S, np.float32)
        ts, te, rt = z.copy(), z.copy(), z.copy()
        ok, valid = z.copy(), z.copy()
        ts[0], te[0], rt[0] = 0.0, 4.0, 4.0
        valid[0] = 1.0  # ok stays 0: a failed call
        tput, rtsum, load = bin_samples(ts, te, rt, ok, valid, 0.0, 1.0,
                                        num_quanta=8)
        assert np.array(tput).sum() == 0.0
        assert np.array(load).sum() == pytest.approx(4.0, abs=1e-4)

    def test_out_of_range_completions_dropped(self):
        z = np.zeros(BLOCK_S, np.float32)
        ts, te, rt = z.copy(), z.copy(), z.copy()
        ok, valid = z.copy(), z.copy()
        # completes after the last quantum; starts before the first
        ts[0], te[0], rt[0] = -10.0, 100.0, 110.0
        ok[0] = valid[0] = 1.0
        tput, _, load = bin_samples(ts, te, rt, ok, valid, 0.0, 1.0,
                                    num_quanta=8)
        assert np.array(tput).sum() == 0.0
        # but it is in flight across all 8 quanta
        np.testing.assert_allclose(np.array(load), np.ones(8), atol=1e-5)

    def test_conservation(self):
        """Every successful in-range completion lands in exactly one bin."""
        rng = np.random.default_rng(7)
        ts, te, rt, ok, valid, _ = make_samples(rng, BLOCK_S, 1500,
                                                t_max=600.0)
        q, quantum = 128, 8.0
        tput, _, _ = bin_samples(ts, te, rt, ok, valid, 0.0, quantum,
                                 num_quanta=q)
        in_range = ((te >= 0) & (te < q * quantum) & (ok > 0)
                    & (valid > 0)).sum()
        assert np.array(tput).sum() == pytest.approx(float(in_range))

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_real=st.integers(0, 2 * BLOCK_S),
           quantum=st.floats(0.5, 50.0),
           t0=st.floats(-100.0, 100.0),
           num_quanta=st.sampled_from([16, 64, 128]))
    def test_hypothesis_sweep(self, seed, n_real, quantum, t0, num_quanta):
        rng = np.random.default_rng(seed)
        ts, te, rt, ok, valid, _ = make_samples(rng, 2 * BLOCK_S, n_real)
        got = bin_samples(ts, te, rt, ok, valid, t0, quantum,
                          num_quanta=num_quanta)
        want = bin_samples_ref(ts, te, rt, ok, valid, t0, quantum,
                               num_quanta)
        np.testing.assert_allclose(np.array(got[0]), want[0], atol=1e-4)
        np.testing.assert_allclose(np.array(got[1]), want[1], rtol=1e-4,
                                   atol=1e-3)
        np.testing.assert_allclose(np.array(got[2]), want[2], rtol=1e-3,
                                   atol=2e-3)

    def test_rejects_unaligned_capacity(self):
        z = np.zeros(100, np.float32)
        with pytest.raises(ValueError, match="multiple"):
            bin_samples(z, z, z, z, z, 0.0, 1.0, num_quanta=8)


class TestBinClients:
    def test_matches_ref(self):
        rng = np.random.default_rng(3)
        ts, te, rt, ok, valid, cid = make_samples(rng, 2 * BLOCK_S, 3500,
                                                  n_clients=40)
        got = bin_clients(ts, te, ok, valid, cid, 100.0, 400.0,
                          num_clients=64)
        want = bin_clients_ref(ts, te, ok, valid, cid, 100.0, 400.0, 64)
        np.testing.assert_allclose(np.array(got[0]), want[0], atol=1e-5)
        np.testing.assert_allclose(np.array(got[1]), want[1], rtol=1e-5)
        np.testing.assert_allclose(np.array(got[2]), want[2], rtol=1e-5)

    def test_never_ran_client_sentinels(self):
        z = np.zeros(BLOCK_S, np.float32)
        done, amin, amax = bin_clients(z, z, z, z, z, 0.0, 1.0,
                                       num_clients=8)
        assert np.array(done).sum() == 0.0
        assert (np.array(amin) > 1e38).all()
        assert (np.array(amax) < -1e38).all()

    def test_window_filtering(self):
        z = np.zeros(BLOCK_S, np.float32)
        ts, te = z.copy(), z.copy()
        ok, valid, cid = z.copy(), z.copy(), z.copy()
        # two completions for client 3: one inside [10, 20], one outside
        for i, end in enumerate([15.0, 25.0]):
            ts[i], te[i] = end - 1.0, end
            ok[i] = valid[i] = 1.0
            cid[i] = 3.0
        done, amin, amax = bin_clients(ts, te, ok, valid, cid, 10.0, 20.0,
                                       num_clients=8)
        assert np.array(done)[3] == 1.0
        # activity span covers BOTH samples (span is window-independent)
        assert np.array(amin)[3] == pytest.approx(14.0)
        assert np.array(amax)[3] == pytest.approx(25.0)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_real=st.integers(0, 2 * BLOCK_S),
           w0=st.floats(0.0, 200.0),
           wlen=st.floats(0.0, 300.0),
           num_clients=st.sampled_from([16, 64, 128]))
    def test_hypothesis_sweep(self, seed, n_real, w0, wlen, num_clients):
        rng = np.random.default_rng(seed)
        ts, te, rt, ok, valid, cid = make_samples(
            rng, 2 * BLOCK_S, n_real, n_clients=num_clients)
        got = bin_clients(ts, te, ok, valid, cid, w0, w0 + wlen,
                          num_clients=num_clients)
        want = bin_clients_ref(ts, te, ok, valid, cid, w0, w0 + wlen,
                               num_clients)
        np.testing.assert_allclose(np.array(got[0]), want[0], atol=1e-5)
        np.testing.assert_allclose(np.array(got[1]), want[1], rtol=1e-5)
        np.testing.assert_allclose(np.array(got[2]), want[2], rtol=1e-5)
