"""End-to-end tests of the L2 ``analyze`` pipeline (vs a numpy oracle),
plus AOT lowering invariants the rust runtime depends on."""

import numpy as np
import pytest

from compile.kernels.ref import (bin_samples_ref, bin_clients_ref,
                                 moving_average_ref)
from compile.model import (AnalyzeConfig, NUM_PARAMS, OUTPUT_NAMES,
                           P_DURATION, P_HALFWIN, P_QUANTUM, P_T0, P_W0,
                           P_W1, analyze, analyze_flat, output_shapes)

CFG = AnalyzeConfig(num_samples=4096, num_quanta=64, num_clients=32,
                    degree=4)


def make_run(seed=0, s=4096, n_real=3000, n_clients=20, t_max=500.0):
    rng = np.random.default_rng(seed)
    ts = rng.uniform(0, t_max, s).astype(np.float32)
    rt = rng.uniform(0.1, 30.0, s).astype(np.float32)
    te = (ts + rt).astype(np.float32)
    ok = (rng.random(s) < 0.9).astype(np.float32)
    valid = np.zeros(s, np.float32)
    valid[:n_real] = 1.0
    cid = rng.integers(0, n_clients, s).astype(np.float32)
    params = np.zeros(NUM_PARAMS, np.float32)
    params[P_T0] = 0.0
    params[P_QUANTUM] = 10.0
    params[P_HALFWIN] = 8.0
    params[P_W0] = 100.0
    params[P_W1] = 400.0
    params[P_DURATION] = t_max + 30.0
    return ts, te, rt, ok, valid, cid, params


class TestAnalyze:
    def setup_method(self):
        self.data = make_run()
        ts, te, rt, ok, valid, cid, params = self.data
        self.out = {k: np.array(v) for k, v in
                    analyze(CFG, ts, te, rt, ok, valid, cid, params).items()}

    def test_series_match_ref(self):
        ts, te, rt, ok, valid, cid, params = self.data
        tput, rtsum, load = bin_samples_ref(ts, te, rt, ok, valid, 0.0,
                                            10.0, CFG.num_quanta)
        np.testing.assert_allclose(self.out["tput"], tput, atol=1e-4)
        np.testing.assert_allclose(self.out["load"], load, rtol=1e-3,
                                   atol=2e-3)
        rt_mean = rtsum / np.maximum(tput, 1.0)
        np.testing.assert_allclose(self.out["rt_mean"], rt_mean, rtol=1e-4,
                                   atol=1e-4)
        np.testing.assert_allclose(
            self.out["rt_ma"], moving_average_ref(rtsum, tput, 8.0),
            rtol=1e-4, atol=1e-4)

    def test_totals(self):
        ts, te, rt, ok, valid, cid, params = self.data
        served = (ok * valid) > 0
        t = self.out["totals"]
        assert t[0] == served.sum()
        assert t[1] == valid.sum() - served.sum()
        assert t[2] == pytest.approx(rt[served].mean(), rel=1e-4)
        assert t[3] == pytest.approx(self.out["load"].max(), rel=1e-6)
        assert t[5] == pytest.approx(rt[served].max(), rel=1e-6)

    def test_per_client_completions(self):
        ts, te, rt, ok, valid, cid, params = self.data
        done, _, _ = bin_clients_ref(ts, te, ok, valid, cid, 100.0, 400.0,
                                     CFG.num_clients)
        np.testing.assert_allclose(self.out["completed"], done, atol=1e-5)

    def test_utilization_bounds(self):
        """util in [0, 1]: a client cannot complete more than everyone."""
        u = self.out["util"]
        assert (u >= 0).all() and (u <= 1.0 + 1e-5).all()

    def test_fairness_consistency(self):
        """fairness = completed / util wherever util > 0."""
        f, u, c = (self.out["fairness"], self.out["util"],
                   self.out["completed"])
        mask = u > 1e-6
        np.testing.assert_allclose(f[mask], c[mask] / u[mask], rtol=1e-4)
        assert (f[~mask] == 0).all()

    def test_active_time_bounds(self):
        a = self.out["active_time"]
        assert (a >= 0).all() and (a <= 300.0 + 1e-3).all()  # w1 - w0

    def test_outputs_finite(self):
        for k, v in self.out.items():
            assert np.isfinite(v).all(), k

    def test_output_shapes_contract(self):
        shapes = output_shapes(CFG)
        assert set(shapes) == set(OUTPUT_NAMES)
        for k, v in self.out.items():
            assert v.shape == shapes[k], k


class TestFairServiceScenario:
    """A synthetic perfectly-fair service: equal utilization, flat
    fairness — the paper's Figure-4 signature."""

    def test_flat_fairness(self):
        n_clients, per_client = 8, 40
        s = 4096
        ts = np.zeros(s, np.float32)
        te = np.zeros(s, np.float32)
        rt = np.zeros(s, np.float32)
        ok = np.zeros(s, np.float32)
        valid = np.zeros(s, np.float32)
        cid = np.zeros(s, np.float32)
        i = 0
        # round-robin completions, 1 s apart, all clients active throughout
        for k in range(per_client):
            for c in range(n_clients):
                ts[i] = k * n_clients + c
                te[i] = ts[i] + 1.0
                rt[i] = 1.0
                ok[i] = valid[i] = 1.0
                cid[i] = c
                i += 1
        params = np.zeros(NUM_PARAMS, np.float32)
        params[P_QUANTUM] = 10.0
        params[P_HALFWIN] = 2.0
        params[P_W0] = 0.0
        params[P_W1] = float(per_client * n_clients + 2)
        params[P_DURATION] = float(per_client * n_clients + 2)
        out = analyze(CFG, ts, te, rt, ok, valid, cid, params)
        u = np.array(out["util"])[:n_clients]
        f = np.array(out["fairness"])[:n_clients]
        # equal utilization across clients (within discretization)
        assert u.std() / u.mean() < 0.1
        assert f.std() / f.mean() < 0.1


class TestAotContract:
    def test_flat_order_is_sorted(self):
        assert OUTPUT_NAMES == sorted(OUTPUT_NAMES)

    def test_flat_wrapper_matches_dict(self):
        ts, te, rt, ok, valid, cid, params = make_run(seed=5)
        d = analyze(CFG, ts, te, rt, ok, valid, cid, params)
        flat = analyze_flat(CFG)(ts, te, rt, ok, valid, cid, params)
        for name, arr in zip(OUTPUT_NAMES, flat):
            np.testing.assert_array_equal(np.array(d[name]), np.array(arr))

    def test_lowered_hlo_has_no_custom_calls(self):
        """The rust CPU PJRT client cannot resolve LAPACK/Mosaic
        custom-calls; the lowered module must be pure HLO."""
        from compile.aot import lower_variant
        cfg = AnalyzeConfig(num_samples=16384)
        text = lower_variant(cfg)
        assert "custom-call" not in text, "non-portable HLO emitted"

    def test_manifest_roundtrip(self, tmp_path):
        from compile.aot import write_manifest, VARIANTS
        write_manifest(str(tmp_path))
        lines = (tmp_path / "manifest.txt").read_text().strip().splitlines()
        assert lines[0] == "format=1"
        assert len(lines) == 1 + len(VARIANTS)
        for line in lines[1:]:
            assert line.startswith("variant name=analyze_s")
            assert "outputs=" in line
