"""Layer-2 JAX model: DiPerF's automated analysis pipeline (paper §3.1.3).

``analyze`` composes the Layer-1 Pallas kernels into the full controller-
side computation: per-quantum series (offered load, throughput, response
time), moving-average and polynomial trend approximations, and per-client
utilization / fairness — i.e. everything behind Figures 3–8 plus the
§1/§5 empirical performance models.

The function is pure and fixed-shape so ``aot.py`` can lower it once per
sample-capacity variant to HLO text; the rust coordinator then runs it
via PJRT with Python entirely off the measurement path.

Metric definitions (paper §4):
  * throughput[q]  — successful completions per quantum.
  * load[q]        — time-averaged number of in-flight requests.
  * rt_mean[q]     — mean response time of completions in the quantum.
  * util[c]        — client c's completions inside the peak window divided
                     by ALL completions that occurred while c was active
                     (activity span clipped to the window).
  * fairness[c]    — completions / utilization (the paper's ratio; for a
                     perfectly fair service it is flat across clients).

Polynomial coefficients are in increasing powers of the *normalized* time
x = 2*(t - t0)/duration - 1; the rust side evaluates with the same
normalization (see rust/src/analysis/).
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import bin_samples, bin_clients, moving_average, polyfit

# Layout of the packed scalar-parameter vector (f32[NUM_PARAMS]).
P_T0 = 0          # global time of quantum 0's left edge (s)
P_QUANTUM = 1     # quantum width (s)
P_HALFWIN = 2     # moving-average half-window, in quanta
P_W0 = 3          # peak-window left edge (global s)
P_W1 = 4          # peak-window right edge (global s)
P_DURATION = 5    # experiment duration (s) — for fit normalization
NUM_PARAMS = 8    # padded for forward compatibility


@dataclass(frozen=True)
class AnalyzeConfig:
    """Static shape configuration for one AOT variant."""
    num_samples: int      # padded sample capacity S (multiple of BLOCK_S)
    num_quanta: int = 512
    num_clients: int = 128
    degree: int = 6

    @property
    def name(self):
        return f"analyze_s{self.num_samples}"


def _window_totals(tput, pos_lo, pos_hi):
    """Completions between fractional quantum positions, via cumsum+interp.

    ``pos`` is in quantum units, clipped to ``[0, Q]``; within a quantum
    the count is interpolated linearly (completions are dense at the
    paper's granularity, so this is the natural continuous estimate).
    """
    q = tput.shape[0]
    cum = jnp.concatenate([jnp.zeros((1,), jnp.float32),
                           jnp.cumsum(tput)])          # (Q+1,) exclusive

    def at(pos):
        pos = jnp.clip(pos, 0.0, float(q))
        idx = jnp.clip(jnp.floor(pos), 0.0, float(q - 1))
        frac = pos - idx
        idx = idx.astype(jnp.int32)
        return jnp.take(cum, idx) + frac * jnp.take(tput, idx)

    return jnp.maximum(at(pos_hi) - at(pos_lo), 0.0)


def analyze(cfg: AnalyzeConfig, t_start, t_end, rt, ok, valid, client_id,
            params):
    """Full automated analysis over one experiment's samples.

    Args:
      cfg: static shapes (see :class:`AnalyzeConfig`).
      t_start, t_end, rt, ok, valid, client_id: ``f32[S]`` sample columns;
        pad unused capacity with ``valid = 0``.
      params: ``f32[NUM_PARAMS]`` packed runtime scalars (see P_* indices).

    Returns a dict of named arrays (flattened to a tuple by the AOT
    wrapper, in sorted-key order — see ``aot.py``).
    """
    t0 = params[P_T0]
    quantum = params[P_QUANTUM]
    halfwin = params[P_HALFWIN]
    w0 = params[P_W0]
    w1 = params[P_W1]
    duration = params[P_DURATION]

    # --- L1: per-quantum binning (MXU scatter) ---------------------------
    tput, rt_sum, load = bin_samples(
        t_start, t_end, rt, ok, valid, t0, quantum,
        num_quanta=cfg.num_quanta)
    rt_mean = rt_sum / jnp.maximum(tput, 1.0)

    # --- L1: moving-average trends (the paper's 160 s window) -----------
    ones = jnp.ones_like(tput)
    rt_ma = moving_average(rt_sum, tput, halfwin)     # count-weighted
    tput_ma = moving_average(tput, ones, halfwin)
    load_ma = moving_average(load, ones, halfwin)

    # --- L1: polynomial trend models -------------------------------------
    centers = t0 + (jnp.arange(cfg.num_quanta, dtype=jnp.float32) + 0.5) \
        * quantum
    xn = 2.0 * (centers - t0) / jnp.maximum(duration, 1e-6) - 1.0
    in_run = (centers - t0 <= duration).astype(jnp.float32)
    poly_rt = polyfit(xn, rt_mean, tput, degree=cfg.degree)
    poly_tput = polyfit(xn, tput, in_run, degree=cfg.degree)
    poly_load = polyfit(xn, load, in_run, degree=cfg.degree)

    # --- L1: per-client aggregation --------------------------------------
    completed, amin, amax = bin_clients(
        t_start, t_end, ok, valid, client_id, w0, w1,
        num_clients=cfg.num_clients)
    ran = (amin <= amax).astype(jnp.float32)
    # Activity span clipped to the peak window.
    a0 = jnp.maximum(amin, w0)
    a1 = jnp.minimum(amax, w1)
    active_time = jnp.maximum(a1 - a0, 0.0) * ran
    # Completions (by anyone) during each client's active span.
    tot_active = _window_totals(tput, (a0 - t0) / quantum,
                                (a1 - t0) / quantum)
    util = jnp.where(tot_active > 0.0, completed / tot_active, 0.0)
    fairness = jnp.where(util > 0.0, completed / jnp.maximum(util, 1e-9),
                         0.0)

    # --- scalar summary ---------------------------------------------------
    served = ok * valid
    total_ok = jnp.sum(served)
    totals = jnp.stack([
        total_ok,                                        # 0 completions
        jnp.sum(valid) - total_ok,                       # 1 failures
        jnp.sum(rt * served) / jnp.maximum(total_ok, 1.0),  # 2 mean rt (s)
        jnp.max(load),                                   # 3 peak load
        jnp.max(tput),                                   # 4 peak tput/quantum
        jnp.max(rt * served),                            # 5 max rt (s)
        jnp.sum(load) * quantum,                         # 6 busy req-seconds
        jnp.float32(0.0),                                # 7 reserved
    ])

    return {
        "active_time": active_time,
        "completed": completed,
        "fairness": fairness,
        "load": load,
        "load_ma": load_ma,
        "poly_load": poly_load,
        "poly_rt": poly_rt,
        "poly_tput": poly_tput,
        "rt_ma": rt_ma,
        "rt_mean": rt_mean,
        "totals": totals,
        "tput": tput,
        "tput_ma": tput_ma,
        "util": util,
    }


# Canonical output ordering for the AOT tuple (and the rust unpacker).
OUTPUT_NAMES = sorted([
    "active_time", "completed", "fairness", "load", "load_ma", "poly_load",
    "poly_rt", "poly_tput", "rt_ma", "rt_mean", "totals", "tput", "tput_ma",
    "util",
])


def analyze_flat(cfg: AnalyzeConfig):
    """Return a fixed-arity function emitting outputs as a sorted tuple."""

    def fn(t_start, t_end, rt, ok, valid, client_id, params):
        out = analyze(cfg, t_start, t_end, rt, ok, valid, client_id, params)
        assert sorted(out.keys()) == OUTPUT_NAMES
        return tuple(out[k] for k in OUTPUT_NAMES)

    return fn


def output_shapes(cfg: AnalyzeConfig):
    """Shape (as a tuple) of each named output, keyed by name."""
    q, c, n = cfg.num_quanta, cfg.num_clients, cfg.degree + 1
    return {
        "active_time": (c,), "completed": (c,), "fairness": (c,),
        "load": (q,), "load_ma": (q,), "poly_load": (n,), "poly_rt": (n,),
        "poly_tput": (n,), "rt_ma": (q,), "rt_mean": (q,), "totals": (8,),
        "tput": (q,), "tput_ma": (q,), "util": (c,),
    }
