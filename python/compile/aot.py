"""AOT lowering: JAX analysis model -> HLO *text* artifacts for rust/PJRT.

Run once at build time (``make artifacts``); the rust coordinator loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and Python
never runs on the measurement path.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (AnalyzeConfig, NUM_PARAMS, OUTPUT_NAMES, analyze_flat,
                    output_shapes)

# Sample-capacity variants.  The rust runtime picks the smallest variant
# that holds the run's sample count (padding the rest with valid = 0).
VARIANTS = [
    AnalyzeConfig(num_samples=16384),
    AnalyzeConfig(num_samples=65536),
    AnalyzeConfig(num_samples=262144),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_variant(cfg: AnalyzeConfig) -> str:
    s = cfg.num_samples
    col = jax.ShapeDtypeStruct((s,), jnp.float32)
    par = jax.ShapeDtypeStruct((NUM_PARAMS,), jnp.float32)
    fn = analyze_flat(cfg)
    lowered = jax.jit(fn).lower(col, col, col, col, col, col, par)
    return to_hlo_text(lowered)


def write_manifest(out_dir: str) -> None:
    """Plain key=value manifest the dependency-light rust side can parse."""
    lines = ["format=1"]
    for cfg in VARIANTS:
        shapes = output_shapes(cfg)
        outs = ";".join(
            f"{name}:{','.join(str(d) for d in shapes[name])}"
            for name in OUTPUT_NAMES)
        lines.append(
            f"variant name={cfg.name} file={cfg.name}.hlo.txt "
            f"samples={cfg.num_samples} quanta={cfg.num_quanta} "
            f"clients={cfg.num_clients} degree={cfg.degree} "
            f"params={NUM_PARAMS} outputs={outs}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="lower a single variant by name (e.g. analyze_s16384)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for cfg in VARIANTS:
        if args.only and cfg.name != args.only:
            continue
        path = os.path.join(args.out_dir, f"{cfg.name}.hlo.txt")
        text = lower_variant(cfg)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path}: {len(text)} chars "
              f"(S={cfg.num_samples}, Q={cfg.num_quanta}, "
              f"C={cfg.num_clients}, D={cfg.degree})")
    write_manifest(args.out_dir)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
