"""Layer-1 Pallas kernels for the DiPerF automated-analysis pipeline.

Each kernel is written for TPU-style tiling (samples streamed HBM->VMEM in
blocks, per-quantum accumulators resident in VMEM, MXU-shaped matmuls for
the binning/Gram contractions) but is lowered with ``interpret=True`` so
the resulting HLO runs on any PJRT backend, including the rust CPU client.

Kernels:
  * :mod:`binning`        — sample -> time-quantum aggregation (throughput,
                            response-time sums, offered-load integral) and
                            per-client aggregation (completions, activity
                            spans).
  * :mod:`moving_average` — banded moving-average smoothing of binned
                            series (the paper's 160 s window).
  * :mod:`polyfit`        — weighted Vandermonde/Gram accumulation for the
                            polynomial trend models.

Pure-jnp oracles for everything live in :mod:`ref` and are enforced by
``python/tests``.
"""

from .binning import bin_samples, bin_clients, BLOCK_S
from .moving_average import moving_average
from .polyfit import gram, cholesky_solve, polyfit

__all__ = [
    "bin_samples",
    "bin_clients",
    "moving_average",
    "gram",
    "cholesky_solve",
    "polyfit",
    "BLOCK_S",
]
