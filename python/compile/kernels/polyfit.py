"""Weighted polynomial least-squares: Gram kernel + tiny Cholesky solve.

The paper fits a polynomial to every reported series ("the polynomial
approximations have been computed for all the data in all experiments")
and proposes them as empirical performance models.  A degree-``D``
weighted fit over ``Q`` points is

    A = V^T diag(w) V          (D+1 x D+1 Gram matrix)
    b = V^T (w * y)
    coef = solve(A, b)

with ``V`` the Vandermonde matrix of the (normalized) abscissae.

TPU shaping: the Gram accumulation is the compute — a ``(D+1, Q) x
(Q, D+1)`` MXU contraction done in one VMEM-resident block (Q = 512,
D+1 <= 8: V is 16 KiB).  The ``(D+1)^2`` solve is negligible and is done
as an *unrolled* jnp Cholesky (plain HLO arithmetic — deliberately NOT
``jnp.linalg.solve``, whose CPU lowering emits a LAPACK custom-call the
rust PJRT client may not resolve).

Abscissae must be pre-normalized to ~[-1, 1] by the caller for f32
conditioning; :func:`polyfit` handles that plus ridge damping.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x_ref, y_ref, w_ref, a_ref, b_ref, *, degree):
    x = x_ref[...]            # (Q,) normalized abscissae
    y = y_ref[...]            # (Q,) ordinates
    w = w_ref[...]            # (Q,) non-negative weights

    # Vandermonde columns x^0 .. x^degree, built by cumulative products so
    # each power is one multiply (degree is static).
    cols = [jnp.ones_like(x)]
    for _ in range(degree):
        cols.append(cols[-1] * x)
    v = jnp.stack(cols, axis=1)                     # (Q, D+1)

    wv = v * w[:, None]
    a_ref[...] = jax.lax.dot_general(
        v, wv, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (D+1, D+1)
    b_ref[...] = jax.lax.dot_general(
        v, (w * y)[:, None],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]   # (D+1,)


@functools.partial(jax.jit, static_argnames=("degree",))
def gram(x, y, w, *, degree):
    """Accumulate the weighted normal equations ``(A, b)`` on the MXU.

    Args:
      x: ``f32[Q]`` abscissae, pre-normalized to roughly ``[-1, 1]``.
      y: ``f32[Q]`` ordinates.
      w: ``f32[Q]`` weights (0 masks a point out).
      degree: static polynomial degree ``D``.

    Returns:
      ``(A, b)``: ``f32[D+1, D+1]`` Gram matrix and ``f32[D+1]`` moment
      vector of the weighted normal equations.
    """
    q = x.shape[0]
    n = degree + 1
    spec = pl.BlockSpec((q,), lambda: (0,))
    kernel = functools.partial(_gram_kernel, degree=degree)
    return pl.pallas_call(
        kernel,
        in_specs=[spec, spec, spec],
        out_specs=[pl.BlockSpec((n, n), lambda: (0, 0)),
                   pl.BlockSpec((n,), lambda: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n, n), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32), w.astype(jnp.float32))


def cholesky_solve(a, b):
    """Solve ``a @ coef = b`` for SPD ``a`` via an unrolled Cholesky.

    ``a`` is ``f32[N, N]`` with static, small ``N`` (the loops unroll at
    trace time into plain HLO arithmetic — no LAPACK custom-calls, so the
    lowered module runs on the rust CPU PJRT client).

    Returns ``f32[N]``.
    """
    n = a.shape[0]
    # L is built row by row as a list-of-rows to keep everything functional.
    l = [[jnp.float32(0.0)] * n for _ in range(n)]
    for i in range(n):
        for j in range(i + 1):
            s = a[i, j]
            for k in range(j):
                s = s - l[i][k] * l[j][k]
            if i == j:
                l[i][j] = jnp.sqrt(jnp.maximum(s, 1e-30))
            else:
                l[i][j] = s / l[j][j]
    # Forward substitution: L z = b.
    z = [jnp.float32(0.0)] * n
    for i in range(n):
        s = b[i]
        for k in range(i):
            s = s - l[i][k] * z[k]
        z[i] = s / l[i][i]
    # Back substitution: L^T coef = z.
    c = [jnp.float32(0.0)] * n
    for i in reversed(range(n)):
        s = z[i]
        for k in range(i + 1, n):
            s = s - l[k][i] * c[k]
        c[i] = s / l[i][i]
    return jnp.stack(c)


@functools.partial(jax.jit, static_argnames=("degree",))
def polyfit(x, y, w, *, degree, ridge=1e-4):
    """Weighted ridge-damped polynomial fit; returns ``f32[D+1]`` coefs.

    Coefficients are in increasing-power order over the *given* (already
    normalized) abscissae.  ``ridge`` scales with ``trace(A)`` so the
    damping is shape-independent; it keeps the solve finite when fewer
    than ``D+1`` points carry weight.
    """
    a, b = gram(x, y, w, degree=degree)
    n = degree + 1
    damp = ridge * (jnp.trace(a) / n + 1e-6)
    return cholesky_solve(a + damp * jnp.eye(n, dtype=jnp.float32), b)
