"""Sample -> time-quantum / per-client aggregation Pallas kernels.

This is the hot spot of DiPerF's automated analysis (paper §3.1.3): every
per-call sample collected by the testers must be folded into

  * per-quantum series  — throughput, response-time sum, offered-load
    integral (the series behind Figures 3 and 6), and
  * per-client aggregates — completions inside the peak window and each
    client's activity span (behind Figures 4, 5, 7, 8).

TPU shaping
-----------
Samples are streamed in ``(BLOCK_S,)`` tiles (grid dim 0); the per-quantum
accumulators are a single ``(R, Q)`` VMEM-resident block whose index map is
invariant in the streaming dimension — the canonical Pallas reduction
idiom.  The bin scatter is expressed as an MXU-shaped contraction:

    contrib[R, BLOCK_S] @ onehot[BLOCK_S, Q]  ->  acc[R, Q]

so the TPU does the scatter as a matmul instead of a serial scatter-add.
The offered-load integral uses an interval-coverage matrix in place of the
one-hot.  Everything is lowered with ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls); the same structure compiles for real TPUs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Samples per grid step.  2^12 f32 lanes * (Q=512) coverage matrix is the
# VMEM high-water mark: 4096*512*4 B = 8 MiB, within the ~16 MiB budget.
BLOCK_S = 4096

_BIG = 3.0e38  # plain float: jnp constants would be captured as consts


def _bin_kernel(ts_ref, te_ref, rt_ref, ok_ref, valid_ref, scal_ref,
                tput_ref, rtsum_ref, load_ref):
    """One streaming step: fold BLOCK_S samples into the (Q,) accumulators."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        tput_ref[...] = jnp.zeros_like(tput_ref)
        rtsum_ref[...] = jnp.zeros_like(rtsum_ref)
        load_ref[...] = jnp.zeros_like(load_ref)

    ts = ts_ref[...]          # (BLOCK_S,) request issue time (global s)
    te = te_ref[...]          # (BLOCK_S,) completion time (global s)
    rt = rt_ref[...]          # (BLOCK_S,) service response time (s)
    ok = ok_ref[...]          # (BLOCK_S,) 1.0 iff served successfully
    valid = valid_ref[...]    # (BLOCK_S,) 1.0 iff a real (non-pad) sample
    t0 = scal_ref[0]          # series origin (global s)
    quantum = scal_ref[1]     # quantum width (s)

    q = tput_ref.shape[-1]
    # Column j covers global time [t0 + j*quantum, t0 + (j+1)*quantum).
    col = jax.lax.broadcasted_iota(jnp.float32, (ts.shape[0], q), 1)
    left = t0 + col * quantum
    right = left + quantum

    # --- completion scatter (throughput + response-time sum) ------------
    # bin index of each completion; one-hot against the column iota.  Bin
    # values are small integers (< Q <= 2^24) so f32 equality is exact.
    bin_idx = jnp.floor((te - t0) / quantum)
    onehot = ((bin_idx[:, None] == col)
              & (bin_idx[:, None] >= 0.0)
              & (bin_idx[:, None] < q)).astype(jnp.float32)
    served = ok * valid
    contrib = jnp.stack([served, served * rt])          # (2, BLOCK_S)
    acc = jax.lax.dot_general(
        contrib, onehot,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # (2, Q)
    tput_ref[...] += acc[0]
    rtsum_ref[...] += acc[1]

    # --- offered-load integral ------------------------------------------
    # A request in flight over [ts, te] contributes its fractional overlap
    # with each quantum; summing overlaps and dividing by the quantum gives
    # the time-averaged number of concurrent requests (paper's "load").
    ov = jnp.clip(jnp.minimum(te[:, None], right)
                  - jnp.maximum(ts[:, None], left),
                  0.0, quantum)
    ov = ov * valid[:, None] / quantum
    load_ref[...] += jnp.sum(ov, axis=0)


@functools.partial(jax.jit, static_argnames=("num_quanta",))
def bin_samples(t_start, t_end, rt, ok, valid, t0, quantum, *, num_quanta):
    """Aggregate per-call samples into per-quantum series.

    Args:
      t_start, t_end, rt, ok, valid: ``f32[S]`` sample columns (``S`` must
        be a multiple of :data:`BLOCK_S`; pad with ``valid = 0``).
      t0: ``f32[]`` global time of quantum 0's left edge.
      quantum: ``f32[]`` quantum width in seconds (> 0).
      num_quanta: static number of quanta ``Q``.

    Returns:
      ``(throughput, rt_sum, load)`` — each ``f32[Q]``.  ``throughput[q]``
      counts successful completions in quantum ``q`` (it is also the
      response-time sample count, since both are binned by completion
      time); ``rt_sum[q]`` sums their response times; ``load[q]`` is the
      time-averaged number of in-flight requests.
    """
    s = t_start.shape[0]
    if s % BLOCK_S != 0:
        raise ValueError(f"sample capacity {s} not a multiple of {BLOCK_S}")
    scalars = jnp.stack([jnp.asarray(t0, jnp.float32),
                         jnp.asarray(quantum, jnp.float32)])
    grid = (s // BLOCK_S,)
    sample_spec = pl.BlockSpec((BLOCK_S,), lambda i: (i,))
    acc_spec = pl.BlockSpec((num_quanta,), lambda i: (0,))
    return pl.pallas_call(
        _bin_kernel,
        grid=grid,
        in_specs=[sample_spec] * 5 + [pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[acc_spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((num_quanta,), jnp.float32)] * 3,
        interpret=True,
    )(t_start.astype(jnp.float32), t_end.astype(jnp.float32),
      rt.astype(jnp.float32), ok.astype(jnp.float32),
      valid.astype(jnp.float32), scalars)


def _client_kernel(ts_ref, te_ref, ok_ref, valid_ref, cid_ref, scal_ref,
                   done_ref, amin_ref, amax_ref):
    """Fold BLOCK_S samples into per-client aggregates."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        done_ref[...] = jnp.zeros_like(done_ref)
        amin_ref[...] = jnp.full_like(amin_ref, _BIG)
        amax_ref[...] = jnp.full_like(amax_ref, -_BIG)

    ts = ts_ref[...]
    te = te_ref[...]
    ok = ok_ref[...]
    valid = valid_ref[...]
    cid = cid_ref[...]        # client id as f32 (exact for id < 2^24)
    w0 = scal_ref[0]          # peak-window left edge (global s)
    w1 = scal_ref[1]          # peak-window right edge

    c = done_ref.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.float32, (ts.shape[0], c), 1)
    member = (cid[:, None] == col)                       # (BLOCK_S, C) bool

    # Completions inside the peak window, scattered by client: MXU matvec.
    inwin = ((te >= w0) & (te <= w1)).astype(jnp.float32) * ok * valid
    done_ref[...] += jax.lax.dot_general(
        inwin[None, :], member.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[0]

    # Activity span: masked min of issue times / max of completion times.
    vmask = member & (valid[:, None] > 0.0)
    amin_ref[...] = jnp.minimum(
        amin_ref[...], jnp.min(jnp.where(vmask, ts[:, None], _BIG), axis=0))
    amax_ref[...] = jnp.maximum(
        amax_ref[...], jnp.max(jnp.where(vmask, te[:, None], -_BIG), axis=0))


@functools.partial(jax.jit, static_argnames=("num_clients",))
def bin_clients(t_start, t_end, ok, valid, client_id, w0, w1, *, num_clients):
    """Aggregate samples per client (for utilization / fairness figures).

    Args:
      t_start, t_end, ok, valid: ``f32[S]`` sample columns.
      client_id: ``f32[S]`` integral client ids in ``[0, num_clients)``.
      w0, w1: ``f32[]`` peak-window bounds (global seconds).
      num_clients: static client capacity ``C``.

    Returns:
      ``(completed, active_min, active_max)`` — each ``f32[C]``.
      ``completed[c]`` counts client ``c``'s successful completions inside
      the window; ``active_min``/``active_max`` bound the client's
      activity span over the whole run (±3e38 when the client never ran).
    """
    s = t_start.shape[0]
    if s % BLOCK_S != 0:
        raise ValueError(f"sample capacity {s} not a multiple of {BLOCK_S}")
    scalars = jnp.stack([jnp.asarray(w0, jnp.float32),
                         jnp.asarray(w1, jnp.float32)])
    grid = (s // BLOCK_S,)
    sample_spec = pl.BlockSpec((BLOCK_S,), lambda i: (i,))
    acc_spec = pl.BlockSpec((num_clients,), lambda i: (0,))
    return pl.pallas_call(
        _client_kernel,
        grid=grid,
        in_specs=[sample_spec] * 5 + [pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[acc_spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((num_clients,), jnp.float32)] * 3,
        interpret=True,
    )(t_start.astype(jnp.float32), t_end.astype(jnp.float32),
      ok.astype(jnp.float32), valid.astype(jnp.float32),
      client_id.astype(jnp.float32), scalars)
