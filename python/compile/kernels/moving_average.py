"""Banded moving-average smoothing kernel.

The paper smooths the per-quantum response-time and throughput series with
a moving average (a 160 s window in Figure 3).  Over a ``Q``-point series
this is a banded weighted average:

    ma[i] = (sum_{|i-j| <= h} num[j]) / (sum_{|i-j| <= h} den[j])

with ``h`` the half-window in quanta.  For count-weighted series (response
times) ``num = rt_sum`` and ``den = completions``; for plain smoothing
``den = ones``.

TPU shaping: ``Q`` is small (512 here), so the whole band matrix fits in
VMEM (512*512*4 B = 1 MiB) and both band contractions are a single MXU
matmul each — far cheaper than a gather-based sliding window.  The window
width is a *runtime* scalar: the band matrix is built from an iota
comparison, so no re-lowering is needed to change the window.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ma_kernel(num_ref, den_ref, scal_ref, ma_ref):
    num = num_ref[...]        # (Q,)
    den = den_ref[...]        # (Q,)
    half = scal_ref[0]        # half-window, in quanta (f32, >= 0)

    q = num.shape[0]
    row = jax.lax.broadcasted_iota(jnp.float32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.float32, (q, q), 1)
    band = (jnp.abs(row - col) <= half).astype(jnp.float32)

    snum = jax.lax.dot_general(
        band, num[:, None],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    sden = jax.lax.dot_general(
        band, den[:, None],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]
    ma_ref[...] = snum / jnp.maximum(sden, 1.0)


@jax.jit
def moving_average(num, den, half_window):
    """Weighted moving average of a binned series.

    Args:
      num: ``f32[Q]`` numerator series (e.g. per-quantum rt sums).
      den: ``f32[Q]`` denominator series (e.g. per-quantum counts); pass
        ones for an unweighted moving average.
      half_window: ``f32[]`` half-window size in quanta.

    Returns:
      ``f32[Q]`` smoothed series; quanta whose window holds no weight
      (``sum den == 0``) return ``num``-window-sum / 1 (i.e. 0 when the
      numerator is empty too).
    """
    q = num.shape[0]
    scal = jnp.stack([jnp.asarray(half_window, jnp.float32)])
    spec = pl.BlockSpec((q,), lambda: (0,))
    return pl.pallas_call(
        _ma_kernel,
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((q,), jnp.float32),
        interpret=True,
    )(num.astype(jnp.float32), den.astype(jnp.float32), scal)
