"""Pure-jnp/numpy oracles for every Layer-1 kernel.

These are the correctness ground truth: deliberately written in the most
obvious (loop/vectorized-numpy) style, with no Pallas, no tiling, and no
clever contractions.  ``python/tests`` asserts the kernels match these
within f32 tolerance across randomized shapes (hypothesis sweeps).
"""

import numpy as np


def bin_samples_ref(t_start, t_end, rt, ok, valid, t0, quantum, num_quanta):
    """Reference for :func:`binning.bin_samples`."""
    t_start = np.asarray(t_start, np.float64)
    t_end = np.asarray(t_end, np.float64)
    rt = np.asarray(rt, np.float64)
    ok = np.asarray(ok, np.float64)
    valid = np.asarray(valid, np.float64)
    tput = np.zeros(num_quanta)
    rtsum = np.zeros(num_quanta)
    load = np.zeros(num_quanta)
    for s in range(len(t_start)):
        if valid[s] == 0.0:
            continue
        if ok[s] > 0.0:
            b = int(np.floor((t_end[s] - t0) / quantum))
            if 0 <= b < num_quanta:
                tput[b] += 1.0
                rtsum[b] += rt[s]
        for q in range(num_quanta):
            left = t0 + q * quantum
            right = left + quantum
            ov = min(t_end[s], right) - max(t_start[s], left)
            if ov > 0:
                load[q] += min(ov, quantum) / quantum
    return tput, rtsum, load


def bin_clients_ref(t_start, t_end, ok, valid, client_id, w0, w1,
                    num_clients):
    """Reference for :func:`binning.bin_clients`."""
    big = float(np.float32(3.0e38))  # match the kernel's f32 sentinel
    done = np.zeros(num_clients)
    amin = np.full(num_clients, big)
    amax = np.full(num_clients, -big)
    for s in range(len(t_start)):
        if valid[s] == 0.0:
            continue
        c = int(client_id[s])
        if not 0 <= c < num_clients:
            continue
        if ok[s] > 0.0 and w0 <= t_end[s] <= w1:
            done[c] += 1.0
        amin[c] = min(amin[c], t_start[s])
        amax[c] = max(amax[c], t_end[s])
    return done, amin, amax


def moving_average_ref(num, den, half_window):
    """Reference for :func:`moving_average.moving_average`."""
    num = np.asarray(num, np.float64)
    den = np.asarray(den, np.float64)
    q = len(num)
    out = np.zeros(q)
    h = float(half_window)
    for i in range(q):
        sn = 0.0
        sd = 0.0
        for j in range(q):
            if abs(i - j) <= h:
                sn += num[j]
                sd += den[j]
        out[i] = sn / max(sd, 1.0)
    return out


def gram_ref(x, y, w, degree):
    """Reference for :func:`polyfit.gram`."""
    x = np.asarray(x, np.float64)
    v = np.stack([x ** k for k in range(degree + 1)], axis=1)
    a = v.T @ (v * np.asarray(w, np.float64)[:, None])
    b = v.T @ (np.asarray(w, np.float64) * np.asarray(y, np.float64))
    return a, b


def polyfit_ref(x, y, w, degree, ridge=1e-4):
    """Reference for :func:`polyfit.polyfit` (same ridge damping)."""
    a, b = gram_ref(x, y, w, degree)
    n = degree + 1
    damp = ridge * (np.trace(a) / n + 1e-6)
    return np.linalg.solve(a + damp * np.eye(n), b)


def polyval_ref(coef, x):
    """Evaluate increasing-power coefficients at ``x``."""
    x = np.asarray(x, np.float64)
    out = np.zeros_like(x)
    for k, c in enumerate(np.asarray(coef, np.float64)):
        out = out + c * x ** k
    return out
