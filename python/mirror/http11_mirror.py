"""Structural mirror of ``rust/src/live/proto/http11.rs``.

The authoring environment has no Rust toolchain (the repo's standing
caveat; CI compiles the tree), so the deterministic assertions guarding
the HTTP/1.1 codec — the unit tests in ``http11.rs`` and the
fixture/property rings of ``rust/tests/http11_conformance.rs`` — are
validated here instead.  This file ports the serializers, the streaming
response parser (``RespParser``), the server-side request parser
(``ReqParser``), Pcg64 (bit-exact integer arithmetic), and the
``util::proptest`` seeding scheme, then:

  * replays every seeded unit test from the ``http11.rs`` test module,
  * parses the checked-in golden fixtures
    (``rust/tests/fixtures/http11/*.bin``) whole, torn at **every** byte
    boundary, and dribbled one byte at a time — asserting the
    conformance suite's expectation table,
  * re-serializes Content-Length transcripts byte-exactly,
  * replays the two property tests with the exact RNG draw sequence
    (same base seed 0xD1_7E2F, same stream 0x5eed, same Lemire
    rejection loop), so a logic bug in the Rust parser's mirror-twin
    fails loudly here before CI ever runs.

Run:  python3 python/mirror/http11_mirror.py
"""

import os
from collections import deque

MASK64 = (1 << 64) - 1
MASK128 = (1 << 128) - 1
PCG_MULT = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645

FIXTURES = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", "rust", "tests", "fixtures", "http11"
)

MAX_LINE = 8 * 1024
MAX_HEADERS = 100


class ProtoError(Exception):
    """Mirror of ``proto::ProtoError`` — the only legal failure mode."""


# ---------------------------------------------------------------------------
# Pcg64 + proptest seeding (bit-exact ports of util::rng / util::proptest)
# ---------------------------------------------------------------------------


class Pcg64:
    def __init__(self, seed, stream):
        self.inc = ((stream << 1) | 1) & MASK128
        self.state = 0
        self._step()
        self.state = (self.state + (seed & MASK64)) & MASK128
        self._step()

    def _step(self):
        self.state = (self.state * PCG_MULT + self.inc) & MASK128

    def next_u64(self):
        self._step()
        xored = ((self.state >> 64) ^ (self.state & MASK64)) & MASK64
        rot = self.state >> 122
        return ((xored >> rot) | (xored << (64 - rot))) & MASK64

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_below(self, bound):
        # Lemire multiply-shift with rejection — the loop must match the
        # Rust draw count exactly or every later draw desynchronizes.
        assert bound > 0
        while True:
            x = self.next_u64()
            m = x * bound
            lo = m & MASK64
            if lo >= bound or lo >= ((-bound) & MASK64) % bound:
                return (m >> 64) & MASK64

    def chance(self, p):
        return self.next_f64() < p


def forall(cases, prop):
    """util::proptest::forall — base seed 0xD1_7E2F, stream 0x5eed."""
    for case in range(cases):
        rng = Pcg64((0xD1_7E2F + case) & MASK64, 0x5EED)
        msg = prop(rng)
        if msg is not None:
            raise AssertionError(f"property failed at case {case}: {msg}")


def gen_vec(rng, lo, hi, gen):
    span = max(hi - lo, 1)
    length = lo + rng.next_below(span)
    return [gen(rng) for _ in range(length)]


# ---------------------------------------------------------------------------
# Serializers
# ---------------------------------------------------------------------------


def reason_phrase(status):
    return {
        100: "Continue",
        200: "OK",
        204: "No Content",
        400: "Bad Request",
        404: "Not Found",
        500: "Internal Server Error",
        503: "Service Unavailable",
    }.get(status, "Status")


def write_request(seq, close):
    conn = "close" if close else "keep-alive"
    return (
        f"GET /diperf?seq={seq} HTTP/1.1\r\nHost: diperf\r\n"
        f"User-Agent: diperf-agent\r\nConnection: {conn}\r\n\r\n"
    ).encode()


def write_response(status, body, close):
    conn = "close" if close else "keep-alive"
    head = (
        f"HTTP/1.1 {status} {reason_phrase(status)}\r\n"
        f"Content-Length: {len(body)}\r\nConnection: {conn}\r\n\r\n"
    ).encode()
    return head + bytes(body)


# ---------------------------------------------------------------------------
# Response parser (client side)
# ---------------------------------------------------------------------------

# states
STATUS_LINE, HEADERS, BODY_FIXED, BODY_UNTIL_EOF, CHUNK_SIZE, CHUNK_DATA, CHUNK_DATA_END, TRAILERS = range(8)
LINE_STATES = {STATUS_LINE, HEADERS, CHUNK_SIZE, CHUNK_DATA_END, TRAILERS}


def _trim(b):
    return b.strip(b" \t")


def _parse_decimal(b):
    if not b or len(b) > 18 or not b.isdigit():
        return None
    return int(b, 10)


def _parse_hex(b):
    if not b or len(b) > 15:
        return None
    try:
        return int(b, 16)
    except ValueError:
        return None


class Response:
    def __init__(self, status, close, body_len, interim, body):
        self.status = status
        self.close = close
        self.body_len = body_len
        self.interim = interim
        self.body = body

    def key(self):
        return (self.status, self.close, self.body_len, self.interim, self.body)


class RespParser:
    def __init__(self, capture=False):
        self.capture = capture
        self.done = deque()
        self.state = STATUS_LINE
        self.line = bytearray()
        self.interim = 0
        self._clear_scratch()

    def _clear_scratch(self):
        self.status = 0
        self.http10 = False
        self.saw_close = False
        self.saw_keepalive = False
        self.content_length = None
        self.chunked = False
        self.headers = 0
        self.remaining = 0
        self.body_len = 0
        self.body = bytearray()

    def feed(self, data):
        i = 0
        while i < len(data):
            if self.state in LINE_STATES:
                b = data[i]
                i += 1
                if b == 0x0A:
                    self._on_line()
                else:
                    if len(self.line) >= MAX_LINE:
                        raise ProtoError("line exceeds MAX_LINE")
                    self.line.append(b)
            elif self.state in (BODY_FIXED, CHUNK_DATA):
                take = min(self.remaining, len(data) - i)
                self._consume_body(data[i : i + take])
                i += take
                self.remaining -= take
                if self.remaining == 0:
                    if self.state == BODY_FIXED:
                        self._finish(False)
                    else:
                        self.state = CHUNK_DATA_END
            else:  # BODY_UNTIL_EOF
                self._consume_body(data[i:])
                i = len(data)

    def pop(self):
        return self.done.popleft() if self.done else None

    def eof(self):
        if self.state == BODY_UNTIL_EOF:
            self._finish(True)
            return
        if self.mid_message():
            raise ProtoError("peer closed the connection mid-response")

    def mid_message(self):
        return self.state != STATUS_LINE or len(self.line) > 0 or self.interim > 0

    def _consume_body(self, data):
        self.body_len += len(data)
        if self.capture:
            self.body.extend(data)

    def _on_line(self):
        if self.line and self.line[-1] == 0x0D:
            del self.line[-1]
        line = bytes(self.line)
        self.line = bytearray()
        if self.state == STATUS_LINE:
            self._on_status_line(line)
        elif self.state == HEADERS:
            self._on_header_line(line)
        elif self.state == CHUNK_SIZE:
            self._on_chunk_size(line)
        elif self.state == CHUNK_DATA_END:
            if line:
                raise ProtoError("chunk payload not terminated by CRLF")
            self.state = CHUNK_SIZE
        else:  # TRAILERS
            if not line:
                self._finish(False)
            elif b":" not in line:
                raise ProtoError("malformed trailer line")

    def _on_status_line(self, line):
        if not line:
            return  # stray CRLF between messages
        if len(line) < 12 or not line.startswith(b"HTTP/1."):
            raise ProtoError("malformed status line")
        minor = line[7:8]
        if minor not in (b"0", b"1"):
            raise ProtoError("unsupported HTTP version")
        if line[8:9] != b" ":
            raise ProtoError("malformed status line")
        d = line[9:12]
        if not d.isdigit():
            raise ProtoError("malformed status code")
        if len(line) > 12 and line[12:13] != b" ":
            raise ProtoError("malformed status line")
        self.status = int(d, 10)
        self.http10 = minor == b"0"
        self.state = HEADERS

    def _on_header_line(self, line):
        if not line:
            return self._on_headers_end()
        self.headers += 1
        if self.headers > MAX_HEADERS:
            raise ProtoError("too many headers")
        if line[0:1] in (b" ", b"\t"):
            raise ProtoError("obsolete header line folding is unsupported")
        colon = line.find(b":")
        if colon < 0:
            raise ProtoError("header line without ':'")
        if colon == 0:
            raise ProtoError("empty header name")
        name = line[:colon].lower()
        value = _trim(line[colon + 1 :])
        if name == b"content-length":
            n = _parse_decimal(value)
            if n is None:
                raise ProtoError("invalid Content-Length")
            if self.content_length is not None and self.content_length != n:
                raise ProtoError("conflicting Content-Length headers")
            self.content_length = n
        elif name == b"transfer-encoding":
            if value.lower() != b"chunked":
                raise ProtoError("unsupported Transfer-Encoding")
            self.chunked = True
        elif name == b"connection":
            for token in value.split(b","):
                token = _trim(token).lower()
                if token == b"close":
                    self.saw_close = True
                elif token == b"keep-alive":
                    self.saw_keepalive = True

    def _on_headers_end(self):
        if 100 <= self.status < 200:
            if self.status == 101:
                raise ProtoError("unexpected 101 Switching Protocols")
            self.interim += 1
            self._clear_scratch()
            self.state = STATUS_LINE
            return
        if self.chunked and self.content_length is not None:
            raise ProtoError("both Content-Length and Transfer-Encoding")
        if self.chunked:
            self.state = CHUNK_SIZE
        elif self.status in (204, 304):
            self._finish(False)
        elif self.content_length == 0:
            self._finish(False)
        elif self.content_length is not None:
            self.remaining = self.content_length
            self.state = BODY_FIXED
        else:
            self.state = BODY_UNTIL_EOF

    def _on_chunk_size(self, line):
        semi = line.find(b";")
        digits = _trim(line[:semi] if semi >= 0 else line)
        n = _parse_hex(digits)
        if n is None:
            raise ProtoError("invalid chunk size")
        if n == 0:
            self.state = TRAILERS
        else:
            self.remaining = n
            self.state = CHUNK_DATA

    def _finish(self, eof_body):
        close = self.saw_close or (self.http10 and not self.saw_keepalive) or eof_body
        self.done.append(
            Response(self.status, close, self.body_len, self.interim, bytes(self.body))
        )
        self.interim = 0
        self._clear_scratch()
        self.state = STATUS_LINE


# ---------------------------------------------------------------------------
# Request parser (server side)
# ---------------------------------------------------------------------------

Q_REQUEST_LINE, Q_HEADERS, Q_BODY_FIXED = range(3)


class ReqParser:
    def __init__(self):
        self.done = deque()
        self.state = None
        self.line = bytearray()
        self.method = ""
        self.target = ""
        self.http10 = False
        self.saw_close = False
        self.saw_keepalive = False
        self.content_length = 0
        self.headers = 0
        self.remaining = 0

    def feed(self, data):
        i = 0
        while i < len(data):
            state = self.state if self.state is not None else Q_REQUEST_LINE
            if state in (Q_REQUEST_LINE, Q_HEADERS):
                b = data[i]
                i += 1
                if b == 0x0A:
                    self._on_line()
                else:
                    if len(self.line) >= MAX_LINE:
                        raise ProtoError("line exceeds MAX_LINE")
                    self.line.append(b)
            else:  # Q_BODY_FIXED
                take = min(self.remaining, len(data) - i)
                i += take
                self.remaining -= take
                if self.remaining == 0:
                    self._finish()

    def pop(self):
        return self.done.popleft() if self.done else None

    def mid_message(self):
        return self.state is not None or len(self.line) > 0

    def _on_line(self):
        if self.line and self.line[-1] == 0x0D:
            del self.line[-1]
        line = bytes(self.line)
        self.line = bytearray()
        state = self.state if self.state is not None else Q_REQUEST_LINE
        if state == Q_REQUEST_LINE:
            if not line:
                return  # stray CRLF between requests
            parts = [p for p in line.split(b" ") if p]
            if len(parts) != 3:
                raise ProtoError("malformed request line")
            m, t, v = parts
            if len(v) != 8 or not v.startswith(b"HTTP/1."):
                raise ProtoError("unsupported HTTP version")
            self.method = m.decode("utf-8", "replace")
            self.target = t.decode("utf-8", "replace")
            self.http10 = v[7:8] == b"0"
            self.state = Q_HEADERS
        else:
            self._on_header_line(line)

    def _on_header_line(self, line):
        if not line:
            if self.content_length > 0:
                self.remaining = self.content_length
                self.state = Q_BODY_FIXED
            else:
                self._finish()
            return
        self.headers += 1
        if self.headers > MAX_HEADERS:
            raise ProtoError("too many headers")
        colon = line.find(b":")
        if colon < 0:
            raise ProtoError("header line without ':'")
        name = line[:colon].lower()
        value = _trim(line[colon + 1 :])
        if name == b"content-length":
            n = _parse_decimal(value)
            if n is None:
                raise ProtoError("invalid Content-Length")
            self.content_length = n
        elif name == b"transfer-encoding":
            raise ProtoError("chunked request bodies are unsupported")
        elif name == b"connection":
            for token in value.split(b","):
                token = _trim(token).lower()
                if token == b"close":
                    self.saw_close = True
                elif token == b"keep-alive":
                    self.saw_keepalive = True

    def _finish(self):
        close = self.saw_close or (self.http10 and not self.saw_keepalive)
        self.done.append((self.method, self.target, close, self.content_length))
        self.method = ""
        self.target = ""
        self.http10 = False
        self.saw_close = False
        self.saw_keepalive = False
        self.content_length = 0
        self.headers = 0
        self.remaining = 0
        self.state = None


def from_http_status(status):
    """metrics::SampleOutcome::from_http_status, as a label."""
    if 200 <= status <= 299:
        return "success"
    if status in (429, 503):
        return "denied"
    return "service_error"


# ---------------------------------------------------------------------------
# Replays
# ---------------------------------------------------------------------------


def parse_all(data):
    p = RespParser(capture=True)
    p.feed(data)
    out = []
    while True:
        r = p.pop()
        if r is None:
            return out
        out.append(r)


def unit_tests():
    # content_length_response_round_trips
    raw = write_response(200, b"hello", False)
    (r,) = parse_all(raw)
    assert (r.status, r.body, r.close) == (200, b"hello", False)
    assert write_response(r.status, r.body, r.close) == raw

    # chunked_body_with_trailers_decodes
    raw = (
        b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n"
        b"4\r\nwiki\r\n5;ext=1\r\npedia\r\n0\r\nX-Sum: 9\r\n\r\n"
    )
    (r,) = parse_all(raw)
    assert (r.body, r.body_len, r.close) == (b"wikipedia", 9, False)

    # pipelined_responses_pop_in_order
    raw = (
        write_response(200, b"a", False)
        + write_response(503, b"busy", False)
        + write_response(500, b"boom", True)
    )
    rs = parse_all(raw)
    assert [r.status for r in rs] == [200, 503, 500]
    assert sum(1 for r in rs if r.close) == 1

    # interim_1xx_is_consumed_and_counted
    raw = b"HTTP/1.1 100 Continue\r\n\r\nHTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nok"
    (r,) = parse_all(raw)
    assert (r.status, r.interim) == (200, 1)

    # read_until_eof_body_completes_on_eof
    p = RespParser(capture=True)
    p.feed(b"HTTP/1.0 200 OK\r\n\r\nstreamed")
    assert p.pop() is None
    p.eof()
    r = p.pop()
    assert (r.body, r.close) == (b"streamed", True)

    # http10_defaults_to_close_unless_keepalive
    assert parse_all(b"HTTP/1.0 200 OK\r\nContent-Length: 0\r\n\r\n")[0].close
    assert not parse_all(
        b"HTTP/1.0 200 OK\r\nConnection: Keep-Alive\r\nContent-Length: 0\r\n\r\n"
    )[0].close
    assert parse_all(b"HTTP/1.1 200 OK\r\nConnection: close\r\nContent-Length: 0\r\n\r\n")[0].close

    # no_body_statuses_need_no_content_length
    r = parse_all(b"HTTP/1.1 204 No Content\r\n\r\n")[0]
    assert (r.status, r.body_len) == (204, 0)
    r = parse_all(b"HTTP/1.1 304 Not Modified\r\nContent-Length: 99\r\n\r\n")[0]
    assert (r.status, r.body_len) == (304, 0)

    # malformed_input_errors_instead_of_panicking
    for bad in [
        b"GARBAGE\r\n\r\n",
        b"HTTP/2 200 OK\r\n\r\n",
        b"HTTP/1.1 2xx Nope\r\n\r\n",
        b"HTTP/1.1 200 OK\r\nContent-Length: twelve\r\n\r\n",
        b"HTTP/1.1 200 OK\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\n",
        b"HTTP/1.1 200 OK\r\nNoColonHere\r\n\r\n",
        b"HTTP/1.1 200 OK\r\n folded: value\r\n\r\n",
        b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\n",
        b"HTTP/1.1 200 OK\r\nContent-Length: 1\r\nTransfer-Encoding: chunked\r\n\r\n",
        b"HTTP/1.1 101 Switching Protocols\r\n\r\n",
    ]:
        p = RespParser()
        try:
            p.feed(bad)
        except ProtoError:
            continue
        raise AssertionError(f"must reject {bad!r}")

    # eof_mid_response_is_an_error
    p = RespParser()
    p.feed(b"HTTP/1.1 200 OK\r\nContent-Length: 10\r\n\r\nhal")
    try:
        p.eof()
        raise AssertionError("EOF mid-body must error")
    except ProtoError:
        pass
    p = RespParser()
    p.feed(b"HTTP/1.1 200 OK\r\nConte")
    try:
        p.eof()
        raise AssertionError("EOF mid-header must error")
    except ProtoError:
        pass
    RespParser().eof()  # clean between messages

    # request_round_trips_through_the_server_parser
    q = ReqParser()
    q.feed(write_request(42, False) + write_request(43, True))
    assert q.pop() == ("GET", "/diperf?seq=42", False, 0)
    assert q.pop() == ("GET", "/diperf?seq=43", True, 0)
    assert q.pop() is None and not q.mid_message()

    # request_with_body_is_consumed
    q = ReqParser()
    q.feed(b"POST /x HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcdGET / HTTP/1.1\r\n\r\n")
    r1, r2 = q.pop(), q.pop()
    assert (r1[0], r1[3]) == ("POST", 4)
    assert r2[0] == "GET"

    print("  unit tests: ok")


# (name, transcript file, needs_eof, [(status, body, close, interim)])
GOLDEN = [
    ("simple_200", "simple_200.bin", False, [(200, b"ok\n", False, 0)]),
    ("chunked_trailers", "chunked_trailers.bin", False, [(200, b"wikipedia", False, 0)]),
    (
        "pipelined_three",
        "pipelined_three.bin",
        False,
        [(200, b"ok\n", False, 0), (503, b"denied\n", False, 0), (500, b"error\n", True, 0)],
    ),
    ("interim_100", "interim_100.bin", False, [(200, b"done", False, 1)]),
    ("close_eof", "close_eof.bin", True, [(200, b"streamed until close", True, 0)]),
]


def golden_fixtures():
    def run(data, pieces, needs_eof):
        p = RespParser(capture=True)
        for piece in pieces:
            p.feed(piece)
        if needs_eof:
            p.eof()
        assert not p.mid_message(), "transcript must end on a message boundary"
        out = []
        while True:
            r = p.pop()
            if r is None:
                return out
            out.append(r)

    for name, fname, needs_eof, want in GOLDEN:
        data = open(os.path.join(FIXTURES, fname), "rb").read()
        variants = [("whole", [data])]
        for split in range(len(data) + 1):
            variants.append((f"split@{split}", [data[:split], data[split:]]))
        variants.append(("dribble", [data[i : i + 1] for i in range(len(data))]))
        for label, pieces in variants:
            got = run(data, pieces, needs_eof)
            assert len(got) == len(want), f"{name}/{label}: {len(got)} responses"
            for g, w in zip(got, want):
                assert (g.status, g.body, g.close, g.interim) == w, f"{name}/{label}: {g.key()}"

    # Content-Length transcripts re-serialize byte-exactly
    for fname in ("simple_200.bin", "pipelined_three.bin"):
        data = open(os.path.join(FIXTURES, fname), "rb").read()
        reser = b"".join(write_response(r.status, r.body, r.close) for r in parse_all(data))
        assert reser == data, f"{fname}: re-serialization drifted"

    # golden requests match the serializer and round-trip at every split
    ka = open(os.path.join(FIXTURES, "request_keepalive.bin"), "rb").read()
    cl = open(os.path.join(FIXTURES, "request_close.bin"), "rb").read()
    assert ka == write_request(7, False), "request_keepalive.bin drifted"
    assert cl == write_request(8, True), "request_close.bin drifted"
    both = ka + cl
    for split in range(len(both) + 1):
        q = ReqParser()
        q.feed(both[:split])
        q.feed(both[split:])
        assert q.pop() == ("GET", "/diperf?seq=7", False, 0)
        assert q.pop() == ("GET", "/diperf?seq=8", True, 0)
        assert q.pop() is None and not q.mid_message()

    # status → outcome taxonomy
    for status, want in [
        (200, "success"),
        (204, "success"),
        (429, "denied"),
        (503, "denied"),
        (400, "service_error"),
        (500, "service_error"),
    ]:
        assert from_http_status(status) == want

    print("  golden fixtures (whole + every split + dribble): ok")


def property_tests():
    # arbitrary_bytes_never_panic_either_parser — same draws, same order
    alphabet = b"HTTP/1.0 2045x\r\n:; -OKContent-LghTransfer\tEncoding"

    def no_panic(rng):
        def byte(r):
            if r.chance(0.7):
                return alphabet[r.next_below(len(alphabet))]
            return r.next_u64() & 0xFF

        data = bytes(gen_vec(rng, 0, 600, byte))
        p = RespParser(capture=True)
        q = ReqParser()
        fed_ok = True
        try:
            p.feed(data)
        except ProtoError:
            fed_ok = False
        try:
            q.feed(data)
        except ProtoError:
            pass
        while q.pop() is not None:
            pass
        if fed_ok:
            try:
                p.eof()
            except ProtoError:
                pass
            while p.pop() is not None:
                pass
        return None

    forall(400, no_panic)

    # generated_pipelines_survive_arbitrary_tearing_and_reserialize
    statuses = [200, 400, 404, 418, 500, 503]

    def pipelines(rng):
        n = 1 + rng.next_below(3)
        stream = b""
        want = []
        for k in range(n):
            status = statuses[rng.next_below(len(statuses))]
            body = bytes(gen_vec(rng, 0, 48, lambda r: r.next_u64() & 0xFF))
            close = k == n - 1 and rng.chance(0.5)
            stream += write_response(status, body, close)
            want.append((status, body, close))
        split = rng.next_below(len(stream) + 1)
        p = RespParser(capture=True)
        p.feed(stream[:split])
        p.feed(stream[split:])
        got = []
        while True:
            r = p.pop()
            if r is None:
                break
            got.append(r)
        if len(got) != len(want):
            return "every pipelined response surfaces"
        reser = b""
        for g, w in zip(got, want):
            if (g.status, g.body, g.close) != w:
                return "response fields preserved across the tear"
            reser += write_response(g.status, g.body, g.close)
        if reser != stream:
            return "byte-exact re-serialization"
        return None

    forall(250, pipelines)
    print("  property rings (400 fuzz + 250 pipeline cases): ok")


def main():
    print("http11 mirror:")
    unit_tests()
    golden_fixtures()
    property_tests()
    print("all mirrored http11 assertions hold")


if __name__ == "__main__":
    main()
