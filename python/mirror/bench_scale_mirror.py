"""Measurement mirror for the scale benches (no Rust toolchain here).

The authoring environment cannot run ``cargo bench`` (the repo's
standing caveat: CI compiles the tree), so the first measured rows of
``BENCH_scale.json`` are produced by this structural mirror instead:

* ``sim/wheel.rs`` is ported line-for-line (three 256-slot levels,
  occupancy bitmaps, the ``released`` watermark, the ``cur`` ordering
  heap) and differentially tested against a reference heap, exactly
  like ``rust/tests/engine_queues.rs``;
* the comparison heap — and the wheel's internal ordering heaps — are
  the SAME pure-Python binary heap, so both queues pay uniform
  interpreter overhead and the wheel-vs-heap ratio reflects algorithmic
  structure (O(1) slot insert vs O(log n) sift), not C-vs-Python;
* the workload mirrors ``presets::bench_scale``: ramp within the first
  tenth of the run, per-tester closed call loops, 30 s sync cadence,
  one churn down-window per tester — with the call cadence thinned
  (CALL_EVERY below) so a single-core pure-Python sweep stays
  tractable;
* the queue-only microbench replays ``queue_rate`` from
  ``rust/benches/bench_scale.rs`` with the same Pcg64 stream and expiry
  distributions;
* the campaign mirror expands the ``campaign_smoke`` grid (2 services x
  loads 3/6/9, 240 virtual s) and measures jobs-1 vs jobs-2 wall time
  with real worker processes;
* the live mirror pushes length-prefixed sample frames from 8 agent
  threads to a controller over a real loopback TCP socket for 10 s.

Wall times, RSS and ratios are honest measurements *of this mirror on
the authoring host* — the document's ``note`` says so, and the CI perf
gate only ever ingests CI-accumulated history, so mirror levels can
never trip it.

Run:  python3 python/mirror/bench_scale_mirror.py all
or stage-by-stage: selftest | queue | sweep | campaign | live | assemble
(stages persist into mirror_results.json next to this file).
"""

import json
import multiprocessing
import os
import socket
import struct
import sys
import threading
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from changepoint_mirror import Pcg64  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.abspath(os.path.join(HERE, "..", ".."))
RESULTS = os.path.join(HERE, "mirror_results.json")

DURATION_S = 300.0
SEED = 42
# Rust's bench_scale offers 1 call/s/tester; the mirror thins the
# closed-loop cadence so 100k testers stay affordable in pure Python.
CALL_EVERY_S = 15.0
SYNC_EVERY_S = 30.0
SERVICE_S = 0.05


# ---------------------------------------------------------------------------
# Queues: pure-Python binary heap + faithful timer-wheel port
# ---------------------------------------------------------------------------


class PyHeap:
    """Binary min-heap on (time, seq), sifts written in Python so the
    heap and the wheel pay the same interpreter tax per operation."""

    __slots__ = ("a",)

    def __init__(self):
        self.a = []

    def __len__(self):
        return len(self.a)

    def push(self, item):
        a = self.a
        a.append(item)
        i = len(a) - 1
        while i > 0:
            p = (i - 1) >> 1
            if a[p] <= a[i]:
                break
            a[p], a[i] = a[i], a[p]
            i = p

    def pop(self):
        a = self.a
        last = a.pop()
        if not a:
            return last
        top, a[0] = a[0], last
        i, n = 0, len(a)
        while True:
            l = 2 * i + 1
            if l >= n:
                break
            if l + 1 < n and a[l + 1] < a[l]:
                l += 1
            if a[i] <= a[l]:
                break
            a[i], a[l] = a[l], a[i]
            i = l
        return top

    def peek(self):
        return self.a[0] if self.a else None


G_BITS = 10
SLOT_BITS = 8
SLOTS = 1 << SLOT_BITS
LEVELS = 3
SLOT_MASK = SLOTS - 1


def _slot_shift(lvl):
    return G_BITS + SLOT_BITS * lvl


def _frame_shift(lvl):
    return G_BITS + SLOT_BITS * (lvl + 1)


class TimerWheel:
    """Port of ``sim::wheel::TimerWheel`` (see rust/src/sim/wheel.rs).

    Items are ``(time_us, seq, payload)`` tuples; the occupancy bitmap
    is one Python int per level (arbitrary-precision ints make the
    next-occupied scan a shift + trailing-zero count)."""

    __slots__ = ("cur", "released", "slots", "occ", "overflow", "n")

    def __init__(self):
        self.cur = PyHeap()
        self.released = 0
        self.slots = [[[] for _ in range(SLOTS)] for _ in range(LEVELS)]
        self.occ = [0] * LEVELS
        self.overflow = PyHeap()
        self.n = 0

    def __len__(self):
        return self.n

    def push(self, item):
        self.n += 1
        if item[0] < self.released:
            self.cur.push(item)
        else:
            self._insert_wheel(item)

    def _insert_wheel(self, item):
        t = item[0]
        rel = self.released
        for lvl in range(LEVELS):
            fs = _frame_shift(lvl)
            if (t >> fs) == (rel >> fs):
                idx = (t >> _slot_shift(lvl)) & SLOT_MASK
                self.slots[lvl][idx].append(item)
                self.occ[lvl] |= 1 << idx
                return
        self.overflow.push(item)

    def pop(self):
        if not len(self.cur) and not self._refill():
            return None
        self.n -= 1
        return self.cur.pop()

    def peek(self):
        if not len(self.cur) and not self._refill():
            return None
        return self.cur.peek()

    def _take(self, lvl, idx):
        self.occ[lvl] &= ~(1 << idx)
        out = self.slots[lvl][idx]
        self.slots[lvl][idx] = []
        return out

    def _next_occupied(self, lvl, start):
        bits = self.occ[lvl] >> start
        if not bits:
            return None
        return start + ((bits & -bits).bit_length() - 1)

    def _refill(self):
        while True:
            if self.n == 0:
                return False
            top = _frame_shift(LEVELS - 1)
            while True:
                s = self.overflow.peek()
                if s is None or (s[0] >> top) != (self.released >> top):
                    break
                self._insert_wheel(self.overflow.pop())
            for lvl in range(LEVELS - 1, 0, -1):
                idx = (self.released >> _slot_shift(lvl)) & SLOT_MASK
                if self.occ[lvl] & (1 << idx):
                    for s in self._take(lvl, idx):
                        self._insert_wheel(s)
            start0 = (self.released >> G_BITS) & SLOT_MASK
            idx = self._next_occupied(0, start0)
            if idx is not None:
                frame = (self.released >> _frame_shift(0)) << _frame_shift(0)
                slot_end = frame + ((idx + 1) << G_BITS)
                if slot_end > self.released:
                    self.released = slot_end
                for s in self._take(0, idx):
                    self.cur.push(s)
                return True
            cascaded = False
            for lvl in range(1, LEVELS):
                shift = _slot_shift(lvl)
                start = (self.released >> shift) & SLOT_MASK
                idx = self._next_occupied(lvl, start)
                if idx is not None:
                    frame = (self.released >> _frame_shift(lvl)) << _frame_shift(lvl)
                    slot_start = frame + (idx << shift)
                    if slot_start > self.released:
                        self.released = slot_start
                    for s in self._take(lvl, idx):
                        self._insert_wheel(s)
                    cascaded = True
                    break
            if cascaded:
                continue
            s = self.overflow.peek()
            if s is None:
                return False
            frame = (s[0] >> top) << top
            if frame > self.released:
                self.released = frame


def make_queue(kind):
    return TimerWheel() if kind == "wheel" else PyHeap()


# ---------------------------------------------------------------------------
# RSS probes (same procfs interfaces as rust/src/bench_util)
# ---------------------------------------------------------------------------


def peak_rss_kb():
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1])
    except OSError:
        pass
    return 0


def reset_peak_rss():
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5")
        return True
    except OSError:
        return False


# ---------------------------------------------------------------------------
# The churn-mirror experiment
# ---------------------------------------------------------------------------

CALL, RESP, SYNC, DOWN, UP = 0, 1, 2, 3, 4


def run_churn(n, queue_kind, duration_s=DURATION_S, call_every_s=CALL_EVERY_S,
              seed=SEED):
    """One churn-mirror run; returns the raw measurements for a row."""
    us = 1_000_000
    horizon = int(duration_s * us)
    call_every = int(call_every_s * us)
    sync_every = int(SYNC_EVERY_S * us)
    service = int(SERVICE_S * us)
    stagger = int(0.1 * duration_s / max(n, 1) * us)
    rng = Pcg64.seed_from(seed)

    q = make_queue(queue_kind)
    seq = 0
    alive = bytearray([1] * n)
    up_at = [0] * n
    for t in range(n):
        start = t * stagger
        q.push((start, seq, CALL, t)); seq += 1
        q.push((start + sync_every, seq, SYNC, t)); seq += 1
        # one PlanetLab-style down-window per tester keeps the fault
        # machinery hot, like scenario "churn"
        d0 = start + int(rng.uniform(0.1, 0.8) * horizon)
        up_at[t] = d0 + 30 * us
        q.push((d0, seq, DOWN, t)); seq += 1
        q.push((up_at[t], seq, UP, t)); seq += 1

    rss_reset = reset_peak_rss()
    events = 0
    samples = 0
    peak_pending = len(q)
    t0 = time.perf_counter()
    while True:
        item = q.pop()
        if item is None:
            break
        at, _, kind, tester = item
        if at > horizon:
            break
        events += 1
        if kind == CALL:
            if alive[tester]:
                q.push((at + service, seq, RESP, tester))
            else:
                q.push((max(at + call_every, up_at[tester]), seq, CALL, tester))
            seq += 1
        elif kind == RESP:
            samples += 1
            q.push((at + call_every - service, seq, CALL, tester)); seq += 1
        elif kind == SYNC:
            q.push((at + sync_every, seq, SYNC, tester)); seq += 1
        elif kind == DOWN:
            alive[tester] = 0
        else:
            alive[tester] = 1
        if len(q) > peak_pending:
            peak_pending = len(q)
    wall = max(time.perf_counter() - t0, 1e-9)
    return {
        "label": "churn-%d-%s-stream%s" % (n, queue_kind,
                                           "" if rss_reset else "-norss"),
        "testers": n,
        "queue": queue_kind,
        "collection": "stream",
        "virtual_s": duration_s,
        "wall_s": wall,
        "events": events,
        "events_per_sec": events / wall,
        "peak_pending": peak_pending,
        "peak_rss_kb": peak_rss_kb(),
        "samples": samples,
    }


# ---------------------------------------------------------------------------
# Queue-only microbenchmark (mirrors queue_rate in bench_scale.rs)
# ---------------------------------------------------------------------------


def queue_rate(kind, resident, total=300_000, iters=3):
    best = None
    for _ in range(iters):
        q = make_queue(kind)
        rng = Pcg64.seed_from(7)
        for i in range(resident):
            q.push((rng.next_below(1 << 27), i, 0, 0))
        seq = resident
        t0 = time.perf_counter()
        for _ in range(total):
            item = q.pop()
            q.push((item[0] + 1 + rng.next_below(1 << 24), seq, 0, 0))
            seq += 1
        dt = time.perf_counter() - t0
        if best is None or dt < best:
            best = dt
    return total / best


# ---------------------------------------------------------------------------
# Campaign mirror (campaign_smoke grid, jobs-1 vs jobs-2)
# ---------------------------------------------------------------------------

SMOKE_CELLS = [
    (svc, load) for svc in ("gram_prews", "http") for load in (3, 6, 9)
]


def _run_cell(cell):
    svc, load = cell
    # campaign_smoke: 240 virtual s, 0.5 s client cadence, churn scenario
    svc_axis = {"gram_prews": 0, "http": 1}[svc]
    r = run_churn(load, "wheel", duration_s=240.0, call_every_s=0.5,
                  seed=SEED + svc_axis)
    return {"load": load, "virtual_s": 240.0, "events": r["events"],
            "samples": r["samples"], "peak_pending": r["peak_pending"]}


def run_campaign(jobs):
    t0 = time.perf_counter()
    if jobs <= 1:
        cells = [_run_cell(c) for c in SMOKE_CELLS]
    else:
        with multiprocessing.Pool(jobs) as pool:
            cells = pool.map(_run_cell, SMOKE_CELLS)
    wall = max(time.perf_counter() - t0, 1e-9)
    events = sum(c["events"] for c in cells)
    return {
        "label": "campaign-campaign_smoke-jobs%d" % jobs,
        "testers": sum(c["load"] for c in cells),
        "queue": "wheel",
        "collection": "stream",
        "virtual_s": sum(c["virtual_s"] for c in cells),
        "wall_s": wall,
        "events": events,
        "events_per_sec": events / wall,
        "peak_pending": max(c["peak_pending"] for c in cells),
        "peak_rss_kb": peak_rss_kb(),
        "samples": sum(c["samples"] for c in cells),
    }


# ---------------------------------------------------------------------------
# Live mirror: 8 agent threads -> controller over loopback TCP
# ---------------------------------------------------------------------------


def run_live(agents=8, duration_s=10.0, client_interval_s=0.05,
             sync_interval_s=1.0):
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(agents)
    port = srv.getsockname()[1]
    frames = [0]
    samples = [0]
    lock = threading.Lock()

    def controller(conn):
        buf = b""
        while True:
            data = conn.recv(65536)
            if not data:
                break
            buf += data
            while len(buf) >= 4:
                ln = struct.unpack(">I", buf[:4])[0]
                if len(buf) < 4 + ln:
                    break
                payload = buf[4:4 + ln]
                buf = buf[4 + ln:]
                count = struct.unpack(">I", payload[:4])[0]
                with lock:
                    frames[0] += 1
                    samples[0] += count
        conn.close()

    def agent(aid):
        c = socket.create_connection(("127.0.0.1", port))
        rng = Pcg64.seed_from(SEED + aid)
        end = time.perf_counter() + duration_s
        pending = 0
        next_sync = time.perf_counter() + sync_interval_s
        while time.perf_counter() < end:
            # one closed-loop "call": a jittered think+service sleep
            time.sleep(client_interval_s * rng.uniform(0.8, 1.2))
            pending += 1
            if time.perf_counter() >= next_sync:
                body = struct.pack(">I", pending) + bytes(8 * pending)
                c.sendall(struct.pack(">I", len(body)) + body)
                pending = 0
                next_sync += sync_interval_s
        if pending:
            body = struct.pack(">I", pending) + bytes(8 * pending)
            c.sendall(struct.pack(">I", len(body)) + body)
        c.close()

    handlers = []

    def acceptor():
        for _ in range(agents):
            conn, _ = srv.accept()
            h = threading.Thread(target=controller, args=(conn,))
            h.start()
            handlers.append(h)

    acc = threading.Thread(target=acceptor)
    acc.start()
    t0 = time.perf_counter()
    workers = [threading.Thread(target=agent, args=(i,)) for i in range(agents)]
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    acc.join()
    for h in handlers:
        h.join()
    srv.close()
    wall = max(time.perf_counter() - t0, 1e-9)
    return {
        "label": "live_smoke-%d-agent_throughput" % agents,
        "testers": agents,
        "queue": "live",
        "collection": "stream",
        "virtual_s": duration_s,
        "wall_s": wall,
        "events": frames[0],
        "events_per_sec": frames[0] / wall,
        "peak_pending": 0,
        "peak_rss_kb": peak_rss_kb(),
        "samples": samples[0],
    }


# ---------------------------------------------------------------------------
# Differential self-test (mirrors rust/tests/engine_queues.rs)
# ---------------------------------------------------------------------------


def selftest():
    rng = Pcg64.seed_from(99)
    wheel, heap = TimerWheel(), PyHeap()
    pending = 0
    got_w, got_h = [], []
    seq = 0
    for _ in range(60_000):
        if pending == 0 or rng.next_f64() < 0.55:
            # mix of near, far and very far expiries across all levels
            r = rng.next_f64()
            if r < 0.6:
                t = rng.next_below(1 << 18)
            elif r < 0.9:
                t = rng.next_below(1 << 27)
            else:
                t = rng.next_below(1 << 36)
            base = got_w[-1][0] if got_w else 0
            item = (base + t, seq, 0, 0)
            seq += 1
            wheel.push(item)
            heap.push(item)
            pending += 1
        else:
            a, b = wheel.pop(), heap.pop()
            got_w.append(a)
            got_h.append(b)
            pending -= 1
    while True:
        a = wheel.pop()
        if a is None:
            break
        got_w.append(a)
        got_h.append(heap.pop())
    assert len(wheel) == 0 and len(heap) == 0
    assert got_w == got_h, "wheel/heap dispatch order diverged"
    print("selftest: %d events, wheel == heap dispatch order" % len(got_w))


# ---------------------------------------------------------------------------
# Stage driver + document assembly
# ---------------------------------------------------------------------------


def load_results():
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            return json.load(f)
    return {}


def save_results(r):
    with open(RESULTS, "w") as f:
        json.dump(r, f, indent=2)


def row_json(r):
    """Byte-format mirror of ScaleRow::json (same field order/precision)."""
    return (
        '{"label":"%s","testers":%d,"queue":"%s","collection":"%s",'
        '"virtual_s":%.1f,"wall_s":%.4f,"events":%d,"events_per_sec":%.1f,'
        '"peak_pending":%d,"peak_rss_kb":%d,"samples":%d}'
        % (r["label"], r["testers"], r["queue"], r["collection"],
           r["virtual_s"], r["wall_s"], r["events"], r["events_per_sec"],
           r["peak_pending"], r["peak_rss_kb"], r["samples"])
    )


NOTE = (
    "Perf trajectory for the scale-out subsystem. Regenerate with `cargo "
    "bench --bench bench_scale` (full sweep: 1k/10k/100k testers; set "
    "DIPERF_BENCH_SIZES to restrict). Campaign fan-out rows (label "
    "`campaign-*-jobsN`) are appended by `diperf campaign --bench-json "
    "BENCH_scale.json` and by `cargo bench --bench campaign_scaling`, which "
    "also records the jobs-1-vs-jobs-N speedup. This checked-in copy seeds "
    "the trajectory with rows measured by python/mirror/bench_scale_mirror.py "
    "on the (single-core) authoring host - a structural mirror of the Rust "
    "benches (ported timer wheel vs a uniform-cost binary heap, thinned call "
    "cadence, real loopback sockets for the live row) used because that host "
    "ships no Rust toolchain. Mirror levels are honest measurements of the "
    "mirror, not of the Rust build; CI's perf gate ingests only CI-"
    "accumulated history, so these seed rows never feed the change-point "
    "detector. Rows measured on developer/CI hardware are comparable only "
    "within one machine generation - diff ratios (wheel_vs_heap_*, "
    "campaign_speedup), not absolute wall times, across machines. Field "
    "semantics: docs/BENCH_scale.md."
)


def assemble():
    r = load_results()
    need = ["sweep", "queue", "campaign", "live"]
    missing = [k for k in need if k not in r]
    if missing:
        raise SystemExit("missing stages: %s" % missing)
    rows = (r["sweep"]["rows"] + [r["campaign"]["jobs1"],
                                  r["campaign"]["jobsN"], r["live"]])
    wheel_at_max = r["sweep"]["wheel_vs_heap_experiment"]
    summary = [
        ("note", json.dumps(NOTE)),
        ("virtual_s", "%.1f" % DURATION_S),
        ("seed", "%d" % SEED),
        ("wheel_vs_heap_experiment", "%.3f" % wheel_at_max),
        ("wheel_vs_heap_queue_only", "%.3f" % r["queue"]["ratio"]),
        ("queue_only_resident", "%d" % r["queue"]["resident"]),
        ("campaign_speedup", "%.3f" % r["campaign"]["speedup"]),
        ("campaign_jobs", "%d" % r["campaign"]["jobs"]),
    ]
    doc = '{\n  "schema": "diperf-bench-scale-v1",\n'
    for k, v in summary:
        doc += '  "%s": %s,\n' % (k, v)
    doc += '  "rows": [\n'
    for i, row in enumerate(rows):
        doc += "    " + row_json(row) + (",\n" if i + 1 < len(rows) else "\n")
    doc += "  ]\n}\n"
    out = os.path.join(REPO, "BENCH_scale.json")
    with open(out, "w") as f:
        f.write(doc)
    print("wrote %s (%d rows)" % (out, len(rows)))


def main():
    stage = sys.argv[1] if len(sys.argv) > 1 else "all"
    sizes = [int(s) for s in os.environ.get(
        "MIRROR_SIZES", "1000,10000,100000").split(",")]
    r = load_results()
    if stage in ("selftest", "all"):
        selftest()
    if stage in ("queue", "all"):
        resident = max(2 * max(sizes), 1000)
        qw = queue_rate("wheel", resident)
        qh = queue_rate("heap", resident)
        r["queue"] = {"wheel": qw, "heap": qh, "ratio": qw / qh,
                      "resident": resident}
        print("queue-only @%d resident: wheel %.0f/s heap %.0f/s ratio %.3f"
              % (resident, qw, qh, qw / qh))
        save_results(r)
    if stage in ("sweep", "all"):
        rows = []
        # retain-vs-stream probe first, like the Rust bench (RSS cannot
        # be masked by later, larger runs); the mirror streams either
        # way, so only the label differs
        probe_n = min(max(sizes), 10_000)
        probe = run_churn(probe_n, "wheel")
        probe["label"] = probe["label"].replace("-stream", "-retain")
        probe["collection"] = "retain"
        print("probe  %-28s %8.2fs  %9d ev  %8.0f ev/s" % (
            probe["label"], probe["wall_s"], probe["events"],
            probe["events_per_sec"]))
        rows.append(probe)
        ratio_at_max = None
        for n in sizes:
            pair = {}
            for kind in ("wheel", "heap"):
                row = run_churn(n, kind)
                print("sweep  %-28s %8.2fs  %9d ev  %8.0f ev/s  peak %d" % (
                    row["label"], row["wall_s"], row["events"],
                    row["events_per_sec"], row["peak_pending"]))
                rows.append(row)
                pair[kind] = row
            ratio_at_max = pair["heap"]["wall_s"] / pair["wheel"]["wall_s"]
            print("       wheel_vs_heap @%d = %.3f" % (n, ratio_at_max))
        r["sweep"] = {"rows": rows, "wheel_vs_heap_experiment": ratio_at_max}
        save_results(r)
    if stage in ("campaign", "all"):
        jobs = 2
        serial = run_campaign(1)
        par = run_campaign(jobs)
        speedup = serial["wall_s"] / par["wall_s"]
        print("campaign: serial %.2fs, jobs%d %.2fs, speedup %.3f" % (
            serial["wall_s"], jobs, par["wall_s"], speedup))
        r["campaign"] = {"jobs1": serial, "jobsN": par, "jobs": jobs,
                         "speedup": speedup}
        save_results(r)
    if stage in ("live", "all"):
        row = run_live()
        print("live: %d frames, %d samples, %.1f samples/s/agent" % (
            row["events"], row["samples"],
            row["samples"] / row["wall_s"] / row["testers"]))
        r["live"] = row
        save_results(r)
    if stage in ("assemble", "all"):
        assemble()


if __name__ == "__main__":
    main()
