"""Numerical mirror of ``rust/src/analysis/changepoint.rs``.

The authoring environment has no Rust toolchain (the repo's standing
caveat; CI compiles the tree), so the deterministic assertions in
``rust/tests/changepoint.rs`` and the changepoint unit tests are
validated here instead: this file ports Pcg64 (bit-exact integer
arithmetic) and the E-Divisive detector (same summation structure) and
replays every seeded test scenario, failing loudly on any mismatch with
the asserted outcomes.

Run:  python3 python/mirror/changepoint_mirror.py
"""

MASK64 = (1 << 64) - 1
MASK128 = (1 << 128) - 1
PCG_MULT = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645


class Pcg64:
    """Bit-exact port of ``diperf::util::Pcg64`` (PCG XSL-RR 128/64)."""

    def __init__(self, seed, stream):
        self.inc = ((stream << 1) | 1) & MASK128
        self.state = 0
        self._step()
        self.state = (self.state + (seed & MASK64)) & MASK128
        self._step()

    @classmethod
    def seed_from(cls, seed):
        return cls(seed, 0xDA3E_39CB_94B9_5BDB)

    def _step(self):
        self.state = (self.state * PCG_MULT + self.inc) & MASK128

    def next_u64(self):
        self._step()
        xored = ((self.state >> 64) ^ (self.state & MASK64)) & MASK64
        rot = self.state >> 122
        return ((xored >> rot) | (xored << (64 - rot))) & MASK64

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform(self, lo, hi):
        return lo + (hi - lo) * self.next_f64()

    def next_below(self, bound):
        while True:
            x = self.next_u64()
            m = x * bound
            lo = m & MASK64
            if lo >= bound or lo >= ((1 << 64) - bound) % bound:
                return m >> 64

    def shuffle(self, xs):
        for i in range(len(xs) - 1, 0, -1):
            j = self.next_below(i + 1)
            xs[i], xs[j] = xs[j], xs[i]


def best_split(xs, min_segment):
    """Mirror of the O(n²) incremental Q(τ) sweep."""
    n = len(xs)
    min_segment = max(min_segment, 1)
    if n < 2 * min_segment:
        return None
    within_x = 0.0
    within_y = sum(
        abs(xs[i] - xs[j]) for i in range(n) for j in range(i + 1, n)
    )
    between = 0.0
    best = None
    for tau in range(1, n):
        moved = xs[tau - 1]
        cross_left = sum(abs(x - moved) for x in xs[: tau - 1])
        cross_right = sum(abs(y - moved) for y in xs[tau:])
        within_x += cross_left
        within_y -= cross_right
        between += cross_right - cross_left
        if tau < min_segment or n - tau < min_segment:
            continue
        m, k = float(tau), float(n - tau)
        e = 2.0 * between / (m * k)
        if tau > 1:
            e -= 2.0 * within_x / (m * (m - 1.0))
        if n - tau > 1:
            e -= 2.0 * within_y / (k * (k - 1.0))
        q = m * k / (m + k) * e
        if best is None or q > best[1]:
            best = (tau, q)
    return best


class Detector:
    def __init__(self, permutations=199, alpha=0.05, min_segment=3,
                 seed=0x5EED_CAFE, max_changepoints=8):
        self.permutations = permutations
        self.alpha = alpha
        self.min_segment = min_segment
        self.seed = seed
        self.max_changepoints = max_changepoints

    def p_value(self, xs, observed, rng):
        shuffled = list(xs)
        reached = 0
        for _ in range(self.permutations):
            rng.shuffle(shuffled)
            got = best_split(shuffled, self.min_segment)
            if got is not None and got[1] >= observed:
                reached += 1
        return (reached + 1) / (self.permutations + 1)

    def _detect_segment(self, xs, offset, out):
        if len(out) >= self.max_changepoints:
            return
        got = best_split(xs, self.min_segment)
        if got is None:
            return
        tau, q = got
        rng = Pcg64(self.seed, ((offset << 32) | len(xs)) & MASK64)
        p = self.p_value(xs, q, rng)
        if p > self.alpha:
            return
        out.append({
            "index": offset + tau,
            "stat": q,
            "p_value": p,
            "before_mean": sum(xs[:tau]) / tau,
            "after_mean": sum(xs[tau:]) / (len(xs) - tau),
        })
        self._detect_segment(xs[:tau], offset, out)
        self._detect_segment(xs[tau:], offset + tau, out)

    def detect(self, xs):
        out = []
        self._detect_segment(list(xs), 0, out)
        out.sort(key=lambda c: c["index"])
        return out


# ---------------------------------------------------------------------------
# Replay of the seeded Rust test scenarios
# ---------------------------------------------------------------------------

FAILURES = []


def check(name, ok, detail=""):
    tag = "PASS" if ok else "FAIL"
    print(f"[{tag}] {name}" + (f"  {detail}" if detail else ""))
    if not ok:
        FAILURES.append(name)


def step_series(n, at, lo, hi, noise, seed=7):
    rng = Pcg64.seed_from(seed)
    return [
        (lo if i < at else hi) + rng.uniform(-noise, noise) for i in range(n)
    ]


def rust_pcg_vectors():
    # sanity-lock the generator against its Rust unit-test behavior
    a, b = Pcg64(42, 7), Pcg64(42, 7)
    check("pcg: deterministic", all(a.next_u64() == b.next_u64()
                                    for _ in range(100)))
    r = Pcg64.seed_from(3)
    ok = all(0.0 <= r.next_f64() < 1.0 for _ in range(10_000))
    check("pcg: f64 in [0,1)", ok)
    r = Pcg64.seed_from(4)
    mean = sum(r.next_f64() for _ in range(100_000)) / 100_000
    check("pcg: f64 mean ~ 0.5", abs(mean - 0.5) < 0.01, f"mean={mean:.4f}")
    r = Pcg64.seed_from(10)
    v = list(range(50))
    r.shuffle(v)
    check("pcg: shuffle is a permutation",
          sorted(v) == list(range(50)) and v != list(range(50)))


def unit_best_split_clean_step():
    xs = step_series(40, 20, 10.0, 20.0, 0.5)
    tau, q = best_split(xs, 3)
    check("unit: clean step found at tau=20, q>10",
          tau == 20 and q > 10.0, f"tau={tau} q={q:.2f}")


def unit_best_split_matches_naive():
    xs = step_series(24, 9, 3.0, 5.0, 1.0)
    n, min_seg = len(xs), 2

    def naive(tau):
        x, y = xs[:tau], xs[tau:]
        m, k = float(len(x)), float(len(y))
        between = sum(abs(a - b) for a in x for b in y)

        def within(s):
            return sum(abs(s[i] - s[j]) for i in range(len(s))
                       for j in range(i + 1, len(s)))

        e = 2.0 * between / (m * k)
        if len(x) > 1:
            e -= 2.0 * within(x) / (m * (m - 1.0))
        if len(y) > 1:
            e -= 2.0 * within(y) / (k * (k - 1.0))
        return m * k / (m + k) * e

    bt, bq = best_split(xs, min_seg)
    max_naive = max(naive(t) for t in range(min_seg, n - min_seg + 1))
    check("unit: incremental Q == naive Q",
          abs(bq - max_naive) < 1e-9 and abs(naive(bt) - bq) < 1e-9,
          f"inc={bq:.6f} naive={max_naive:.6f}")


def unit_detector_step_and_null():
    det = Detector()
    xs = step_series(50, 25, 100.0, 140.0, 3.0)
    cps = det.detect(xs)
    ok = cps and any(abs(c["index"] - 25) <= 1 for c in cps)
    check("unit: 50-pt step detected at 25±1", bool(ok),
          f"indices={[c['index'] for c in cps]} "
          f"p={[round(c['p_value'], 3) for c in cps]}")
    rng = Pcg64.seed_from(11)
    null = [rng.uniform(100.0, 106.0) for _ in range(50)]
    cps = det.detect(null)
    check("unit: null series (seed 11) quiet", not cps,
          f"spurious={[(c['index'], round(c['p_value'], 3)) for c in cps]}")


def unit_hierarchical_two_shifts():
    xs = step_series(30, 15, 10.0, 30.0, 0.5) + step_series(
        15, 0, 60.0, 60.0, 0.5
    )
    cps = Detector().detect(xs)
    idx = [c["index"] for c in cps]
    ok = (len(cps) >= 2 and any(abs(i - 15) <= 1 for i in idx)
          and any(abs(i - 30) <= 1 for i in idx))
    check("unit: hierarchical finds shifts at 15 and 30", ok, f"idx={idx}")


def integ_shift_50pts():
    rng = Pcg64.seed_from(1234)
    all_ok = True
    detail = []
    for shift_at, lo, hi, noise in [(25, 100.0, 130.0, 4.0),
                                    (25, 1.0e6, 0.8e6, 0.02e6)]:
        xs = [(lo if i < shift_at else hi) + rng.uniform(-noise, noise)
              for i in range(50)]
        cps = Detector().detect(xs)
        idx = [c["index"] for c in cps]
        ok = cps and any(abs(i - shift_at) <= 1 for i in idx)
        detail.append(f"{lo}->{hi}: idx={idx}")
        all_ok = all_ok and bool(ok)
    check("integ: injected shifts at 25±1 (both polarities)", all_ok,
          "; ".join(detail))


def integ_null_seeds():
    det = Detector()
    bad = []
    for seed in [2, 3, 5, 8, 13]:
        rng = Pcg64.seed_from(seed)
        xs = [rng.uniform(95.0, 105.0) for _ in range(50)]
        cps = det.detect(xs)
        if cps:
            bad.append((seed, [(c["index"], round(c["p_value"], 3))
                               for c in cps]))
    check("integ: null seeds 2,3,5,8,13 all quiet", not bad, f"bad={bad}")


def integ_fixture():
    import json
    import os

    root = os.path.join(os.path.dirname(__file__), "..", "..")
    fx = os.path.join(root, "rust", "tests", "fixtures", "perf_gate")

    def eps_series(*names):
        out = {}
        for name in names:
            doc = json.load(open(os.path.join(fx, name)))
            for row in doc["rows"]:
                for metric in ("wall_s", "events_per_sec", "peak_pending",
                               "peak_rss_kb"):
                    key = f"{row['label']}/{metric}"
                    out.setdefault(key, []).append(float(row[metric]))
        return out

    det = Detector()
    healthy = eps_series("history_good.json")
    noisy = {k: det.detect(v) for k, v in healthy.items()}
    quiet = all(not v for v in noisy.values())
    check("integ: healthy fixture quiet on every series", quiet,
          f"alarms={[(k, [c['index'] for c in v]) for k, v in noisy.items() if v]}")

    both = eps_series("history_good.json", "history_regression.json")
    eps = both["churn-1000-wheel/events_per_sec"]
    check("integ: fixture series length 13", len(eps) == 13, f"n={len(eps)}")
    cps = det.detect(eps)
    idx = [c["index"] for c in cps]
    ok = cps and any(abs(i - 10) <= 1 for i in idx)
    check("integ: regression detected at 10±1", bool(ok),
          f"idx={idx} p={[round(c['p_value'], 3) for c in cps]}")
    if cps:
        c = [c for c in cps if abs(c["index"] - 10) <= 1][0]
        check("integ: regression direction down",
              c["before_mean"] > c["after_mean"])
        check("integ: regression fresh (window 5)",
              c["index"] + 5 >= len(eps))
    wall = both["churn-1000-wheel/wall_s"]
    cps_w = det.detect(wall)
    check("integ: wall_s shift detected too", bool(cps_w),
          f"idx={[c['index'] for c in cps_w]}")


def main():
    rust_pcg_vectors()
    unit_best_split_clean_step()
    unit_best_split_matches_naive()
    unit_detector_step_and_null()
    unit_hierarchical_two_shifts()
    integ_shift_50pts()
    integ_null_seeds()
    integ_fixture()
    print()
    if FAILURES:
        print(f"{len(FAILURES)} scenario(s) FAILED: {FAILURES}")
        raise SystemExit(1)
    print("all changepoint scenarios validated")


if __name__ == "__main__":
    main()
